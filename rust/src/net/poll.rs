//! Tiny epoll + eventfd wrapper for the net server's readiness event loop.
//!
//! The crate is dependency-light by design (no tokio/mio/libc), so the two
//! syscall families the event loop needs — `epoll_*` and `eventfd` — are
//! declared here as a minimal FFI shim. Linux-only, like the CI matrix.
//!
//! Two types:
//!
//! - [`Poller`]: an `epoll` instance. Register file descriptors with a
//!   caller-chosen `u64` token and an [`Interest`] (read/write), then
//!   [`Poller::wait`] for readiness events. Level-triggered: an event
//!   repeats every wait until the fd is drained (read) or the interest is
//!   dropped (write), which keeps the consumer logic simple — no starved
//!   wakeup can be "lost".
//! - [`WakeFd`]: an `eventfd` used to interrupt a blocked `wait` from
//!   another thread (reply pumps and the acceptor wake workers through
//!   these). [`WakeFd::wake`] is async-signal-safe cheap (one 8-byte
//!   write); [`WakeFd::drain`] resets it from the owning loop.

use crate::error::Result;
use std::io;
use std::time::Duration;

/// Raw syscall surface. Kept private to the module; everything public goes
/// through the safe wrappers below.
mod sys {
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const EFD_NONBLOCK: i32 = 0o4000;

    /// `struct epoll_event` is packed on x86-64 (the kernel ABI predates
    /// alignment-aware layouts); other architectures use natural layout.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }
}

fn last_err() -> io::Error {
    io::Error::last_os_error()
}

/// Which readiness conditions a registration subscribes to.
///
/// Error/hangup conditions (`EPOLLERR`/`EPOLLHUP`) are always reported by
/// the kernel regardless of interest; they surface as
/// [`PollEvent::readable`] + [`PollEvent::closed`] so consumers notice on
/// their next read attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    /// Subscribe to read readiness (`EPOLLIN` + `EPOLLRDHUP`).
    pub read: bool,
    /// Subscribe to write readiness (`EPOLLOUT`).
    pub write: bool,
}

impl Interest {
    /// Read readiness only.
    pub const READ: Interest = Interest { read: true, write: false };
    /// Write readiness only.
    pub const WRITE: Interest = Interest { read: false, write: true };
    /// Both read and write readiness.
    pub const BOTH: Interest = Interest { read: true, write: true };
    /// Neither (registration kept, no wakeups except errors/hangup).
    pub const NONE: Interest = Interest { read: false, write: false };

    fn mask(self) -> u32 {
        let mut m = 0;
        if self.read {
            m |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if self.write {
            m |= sys::EPOLLOUT;
        }
        m
    }
}

/// One readiness event returned by [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered with.
    pub token: u64,
    /// The fd is readable (or has an error/hangup pending — reading
    /// surfaces it).
    pub readable: bool,
    /// The fd is writable.
    pub writable: bool,
    /// The peer closed or the fd errored (`EPOLLERR`/`EPOLLHUP`/
    /// `EPOLLRDHUP`). Still read until EOF to drain buffered bytes.
    pub closed: bool,
}

/// A level-triggered `epoll` instance.
///
/// Not `Clone`: exactly one thread owns a `Poller` and calls `wait` on it.
/// Registration/deregistration from the owning thread only (the server
/// routes cross-thread requests through a [`WakeFd`] + command queue).
#[derive(Debug)]
pub struct Poller {
    epfd: i32,
    buf: Vec<sys::EpollEvent>,
}

impl Poller {
    /// Create a new epoll instance (close-on-exec).
    pub fn new() -> Result<Poller> {
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(last_err().into());
        }
        Ok(Poller { epfd, buf: vec![sys::EpollEvent { events: 0, data: 0 }; 256] })
    }

    fn ctl(&self, op: i32, fd: i32, token: u64, interest: Interest) -> Result<()> {
        let mut ev = sys::EpollEvent { events: interest.mask(), data: token };
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(last_err().into());
        }
        Ok(())
    }

    /// Register `fd` under `token` with the given interest.
    pub fn register(&self, fd: i32, token: u64, interest: Interest) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, interest)
    }

    /// Change the interest (and/or token) of an already-registered fd.
    pub fn modify(&self, fd: i32, token: u64, interest: Interest) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, interest)
    }

    /// Remove `fd` from the interest set. Harmless if the fd was already
    /// closed (the kernel auto-deregisters closed fds).
    pub fn deregister(&self, fd: i32) -> Result<()> {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        let rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
        if rc < 0 {
            let e = last_err();
            // ENOENT/EBADF after a racing close is not an error worth
            // surfacing to the loop.
            if e.raw_os_error() != Some(2) && e.raw_os_error() != Some(9) {
                return Err(e.into());
            }
        }
        Ok(())
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// elapses (`None` blocks indefinitely). Ready events are appended to
    /// `out` (which is cleared first).
    pub fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let timeout_ms: i32 = match timeout {
            None => -1,
            Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
        };
        let n = loop {
            let n = unsafe {
                sys::epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
            };
            if n >= 0 {
                break n as usize;
            }
            let e = last_err();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e.into());
        };
        for ev in &self.buf[..n] {
            // Packed struct: copy fields by value, never by reference.
            let events = ev.events;
            let token = ev.data;
            out.push(PollEvent {
                token,
                readable: events & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP)
                    != 0,
                writable: events & (sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP) != 0,
                closed: events & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0,
            });
        }
        if n == self.buf.len() {
            // Saturated the event buffer: grow so a large connection count
            // doesn't force extra wait() round-trips.
            self.buf.resize(self.buf.len() * 2, sys::EpollEvent { events: 0, data: 0 });
        }
        Ok(())
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

// The epoll fd is just an int; registration/wait safety is the owning
// thread's concern (enforced by &mut on wait).
unsafe impl Send for Poller {}

/// An `eventfd`-backed wakeup handle.
///
/// Cloneable-by-reference across threads (`&WakeFd: Send + Sync`): any
/// thread may [`wake`](WakeFd::wake); the loop that registered
/// [`raw`](WakeFd::raw) in its poller calls [`drain`](WakeFd::drain) when
/// the token fires.
#[derive(Debug)]
pub struct WakeFd {
    fd: i32,
}

impl WakeFd {
    /// Create a nonblocking close-on-exec eventfd.
    pub fn new() -> Result<WakeFd> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(last_err().into());
        }
        Ok(WakeFd { fd })
    }

    /// The raw fd, for registering with a [`Poller`] (read interest).
    pub fn raw(&self) -> i32 {
        self.fd
    }

    /// Make the fd readable, waking any poller watching it. Idempotent
    /// while pending: if the counter is already saturated (`WouldBlock`),
    /// the wakeup is already queued and the call is a no-op.
    pub fn wake(&self) {
        let one: u64 = 1;
        let buf = one.to_ne_bytes();
        unsafe { sys::write(self.fd, buf.as_ptr(), buf.len()) };
    }

    /// Reset the counter so the next [`wake`](WakeFd::wake) triggers a
    /// fresh readiness event. Returns `true` if at least one wake was
    /// pending.
    pub fn drain(&self) -> bool {
        let mut buf = [0u8; 8];
        let n = unsafe { sys::read(self.fd, buf.as_mut_ptr(), buf.len()) };
        n == 8
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe { sys::close(self.fd) };
    }
}

unsafe impl Send for WakeFd {}
unsafe impl Sync for WakeFd {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Instant;

    #[test]
    fn wakefd_wakes_and_drains() {
        let mut poller = Poller::new().unwrap();
        let wake = WakeFd::new().unwrap();
        poller.register(wake.raw(), 7, Interest::READ).unwrap();

        let mut events = Vec::new();
        // Nothing pending: a short wait times out empty.
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());

        wake.wake();
        wake.wake(); // coalesces with the first
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        assert!(wake.drain());
        assert!(!wake.drain()); // already reset
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn wake_from_other_thread_interrupts_wait() {
        let mut poller = Poller::new().unwrap();
        let wake = std::sync::Arc::new(WakeFd::new().unwrap());
        poller.register(wake.raw(), 1, Interest::READ).unwrap();

        let w = wake.clone();
        let t0 = Instant::now();
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            w.wake();
        });
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        j.join().unwrap();
        assert_eq!(events.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn tcp_read_and_write_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 42, Interest::READ).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "no data yet");

        client.write_all(b"ping").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);
        let mut buf = [0u8; 8];
        let n = (&server).read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        // Toggling in write interest reports writable immediately (socket
        // buffer is empty).
        poller.modify(server.as_raw_fd(), 42, Interest::BOTH).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.writable));

        // Peer close surfaces as a readable/closed event.
        poller.modify(server.as_raw_fd(), 42, Interest::READ).unwrap();
        drop(client);
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.closed));

        poller.deregister(server.as_raw_fd()).unwrap();
    }
}
