//! Batch aggregation kernels: apply a **run** of gathered updates to one
//! state with tight slice loops.
//!
//! The plan's dispatch pass no longer mutates [`AggState`] inline; it
//! gathers each batch's `(seq, value, raw_hash)` rows into per-(metric,
//! slot) columnar buffers (see `plan::dispatch`) and flushes them through
//! these entry points. Hoisting the work out of the per-event loop buys:
//!
//! * one enum match per run instead of one per row;
//! * one slot resolution + dirty-mark per run instead of one per row;
//! * no per-row aggregate-value computation on non-emitting runs — the
//!   scalar path paid a division (AVG), a division + `sqrt` (STDDEV) or
//!   a map probe on **every** add/evict, emitted or not;
//! * moment updates become plain slice sweeps (`sum += v` / `sumsq += v*v`
//!   over `&[f64]`) with independent accumulator chains the CPU can
//!   pipeline.
//!
//! ## Bit-identity contract
//!
//! Accumulation is **in row order** — no pairwise/SIMD reassociation of
//! float sums — so a run produces exactly the state bytes the scalar
//! `add`/`evict` sequence would. The emitting kernel computes per-row
//! values through the same shared helpers (`Moments::value_of`,
//! `Welford::value`) the scalar [`AggState::value`] uses. Reply streams
//! and persisted states are therefore byte-identical across paths
//! (`rust/tests/batch_equivalence.rs` is the referee). The win comes from
//! removing per-row dispatch overhead, not from changing float math.

use crate::agg::state::MonoEntry;
use crate::agg::AggState;

/// Apply a run of window **arrivals** (no replies needed — backfill,
/// non-zero-offset bundles, hopping pane maintenance).
///
/// Columns are parallel: `vals[i]` and `hashes[i]` belong to the event
/// with sequence `seqs[i]`; rows are in dispatch order.
pub fn add_run(st: &mut AggState, seqs: &[u64], vals: &[f64], hashes: &[u64]) {
    debug_assert_eq!(seqs.len(), vals.len());
    debug_assert_eq!(seqs.len(), hashes.len());
    match st {
        AggState::Moments(_, m) => {
            let (mut sum, mut sumsq) = (m.sum, m.sumsq);
            for &v in vals {
                sum += v;
                sumsq += v * v;
            }
            m.sum = sum;
            m.sumsq = sumsq;
            m.count += vals.len() as u64;
        }
        AggState::Extremum { is_min, deque } => {
            let is_min = *is_min;
            for (i, &v) in vals.iter().enumerate() {
                while let Some(back) = deque.back() {
                    let keep = if is_min { back.value < v } else { back.value > v };
                    if keep {
                        break;
                    }
                    deque.pop_back();
                }
                deque.push_back(MonoEntry { seq: seqs[i], value: v });
            }
        }
        AggState::Distinct(map) => {
            for &h in hashes {
                *map.entry(h).or_insert(0) += 1;
            }
        }
        AggState::Anomaly(w) => {
            for &v in vals {
                w.add(v);
            }
        }
    }
}

/// Apply a run of window **expirations** (never emits; rows are in
/// dispatch order, which for expirations is seq order).
pub fn evict_run(st: &mut AggState, seqs: &[u64], vals: &[f64], hashes: &[u64]) {
    debug_assert_eq!(seqs.len(), vals.len());
    debug_assert_eq!(seqs.len(), hashes.len());
    match st {
        AggState::Moments(_, m) => {
            // the scalar path resets sum/sumsq exactly when count hits
            // zero (drift cancellation); a run that cannot empty the
            // window takes the branch-free sweep
            if (m.count as usize) > vals.len() {
                let (mut sum, mut sumsq) = (m.sum, m.sumsq);
                for &v in vals {
                    sum -= v;
                    sumsq -= v * v;
                }
                m.sum = sum;
                m.sumsq = sumsq;
                m.count -= vals.len() as u64;
            } else {
                for &v in vals {
                    debug_assert!(m.count > 0, "evict from empty aggregation");
                    m.count = m.count.saturating_sub(1);
                    m.sum -= v;
                    m.sumsq -= v * v;
                    if m.count == 0 {
                        m.sum = 0.0;
                        m.sumsq = 0.0;
                    }
                }
            }
        }
        AggState::Extremum { deque, .. } => {
            for &seq in seqs {
                if let Some(front) = deque.front() {
                    if front.seq == seq {
                        deque.pop_front();
                    }
                }
            }
        }
        AggState::Distinct(map) => {
            for &h in hashes {
                if let Some(c) = map.get_mut(&h) {
                    debug_assert!(*c > 0, "distinct evict below zero multiplicity");
                    *c = c.saturating_sub(1);
                    if *c == 0 {
                        map.remove(&h);
                    }
                }
            }
        }
        AggState::Anomaly(w) => {
            for &v in vals {
                w.evict(v);
            }
        }
    }
}

/// Apply a run of **live arrivals**, recording the post-row aggregate
/// value for each (one reply per row). Rows with `incl[i] == false` are
/// excluded from the aggregate (SQL null semantics) but still produce the
/// current value for their reply, exactly like the scalar path's
/// read-only `state.value()`.
pub fn add_run_emit(
    st: &mut AggState,
    seqs: &[u64],
    vals: &[f64],
    hashes: &[u64],
    incl: &[bool],
    out: &mut Vec<Option<f64>>,
) {
    debug_assert_eq!(seqs.len(), vals.len());
    debug_assert_eq!(seqs.len(), hashes.len());
    debug_assert_eq!(seqs.len(), incl.len());
    match st {
        AggState::Moments(kind, m) => {
            let kind = *kind;
            for (i, &v) in vals.iter().enumerate() {
                if incl[i] {
                    m.count += 1;
                    m.sum += v;
                    m.sumsq += v * v;
                }
                out.push(m.value_of(kind));
            }
        }
        AggState::Extremum { is_min, deque } => {
            let is_min = *is_min;
            for (i, &v) in vals.iter().enumerate() {
                if incl[i] {
                    while let Some(back) = deque.back() {
                        let keep = if is_min { back.value < v } else { back.value > v };
                        if keep {
                            break;
                        }
                        deque.pop_back();
                    }
                    deque.push_back(MonoEntry { seq: seqs[i], value: v });
                }
                out.push(deque.front().map(|e| e.value));
            }
        }
        AggState::Distinct(map) => {
            for (i, &h) in hashes.iter().enumerate() {
                if incl[i] {
                    *map.entry(h).or_insert(0) += 1;
                }
                out.push(Some(map.len() as f64));
            }
        }
        AggState::Anomaly(w) => {
            for (i, &v) in vals.iter().enumerate() {
                if incl[i] {
                    w.add(v);
                }
                out.push(w.value());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::AggKind;
    use crate::util::rng::Rng;

    const ALL: [AggKind; 8] = [
        AggKind::Count,
        AggKind::Sum,
        AggKind::Avg,
        AggKind::Min,
        AggKind::Max,
        AggKind::StdDev,
        AggKind::CountDistinct,
        AggKind::AnomalyScore,
    ];

    /// Kernels must equal the scalar add/evict sequence **bitwise** —
    /// states and per-row emitted values alike.
    #[test]
    fn runs_match_scalar_sequence_bitwise() {
        let mut rng = Rng::new(0xA66);
        for kind in ALL {
            let mut scalar = AggState::new(kind);
            let mut kerneled = AggState::new(kind);
            let mut seq = 0u64;
            let mut window: std::collections::VecDeque<(u64, f64, u64)> = Default::default();
            for round in 0..40 {
                let n = rng.index(24) + 1;
                let rows: Vec<(u64, f64, u64)> = (0..n)
                    .map(|_| {
                        let v = (rng.next_f64() * 100.0) - 30.0;
                        let s = seq;
                        seq += 1;
                        (s, v, rng.next_below(8))
                    })
                    .collect();
                let seqs: Vec<u64> = rows.iter().map(|r| r.0).collect();
                let vals: Vec<f64> = rows.iter().map(|r| r.1).collect();
                let hashes: Vec<u64> = rows.iter().map(|r| r.2).collect();
                let incl: Vec<bool> = rows.iter().map(|r| r.2 != 0).collect();

                if round % 3 == 2 {
                    // emitting run: compare per-row values too
                    let mut out = Vec::new();
                    add_run_emit(&mut kerneled, &seqs, &vals, &hashes, &incl, &mut out);
                    for (i, r) in rows.iter().enumerate() {
                        if incl[i] {
                            scalar.add(r.0, r.1, r.2);
                            window.push_back(*r);
                        }
                        let expect = scalar.value();
                        assert_eq!(
                            out[i].map(f64::to_bits),
                            expect.map(f64::to_bits),
                            "{kind:?} emit row {i}"
                        );
                    }
                } else {
                    add_run(&mut kerneled, &seqs, &vals, &hashes);
                    for r in &rows {
                        scalar.add(r.0, r.1, r.2);
                        window.push_back(*r);
                    }
                }
                assert_eq!(kerneled, scalar, "{kind:?} after add round {round}");

                // evict a prefix of the live window through both paths
                let k = rng.index(window.len() + 1);
                let evicted: Vec<(u64, f64, u64)> = window.drain(..k).collect();
                let seqs: Vec<u64> = evicted.iter().map(|r| r.0).collect();
                let vals: Vec<f64> = evicted.iter().map(|r| r.1).collect();
                let hashes: Vec<u64> = evicted.iter().map(|r| r.2).collect();
                evict_run(&mut kerneled, &seqs, &vals, &hashes);
                for r in &evicted {
                    scalar.evict(r.0, r.1, r.2);
                }
                assert_eq!(kerneled, scalar, "{kind:?} after evict round {round}");
            }
        }
    }

    #[test]
    fn evict_run_empties_window_with_drift_reset() {
        let vals = [3.5, 1.25, -2.0, 9.75];
        let seqs = [0u64, 1, 2, 3];
        let hashes = [0u64; 4];
        for kind in [AggKind::Sum, AggKind::StdDev, AggKind::AnomalyScore] {
            let mut st = AggState::new(kind);
            add_run(&mut st, &seqs, &vals, &hashes);
            evict_run(&mut st, &seqs, &vals, &hashes);
            assert_eq!(st, AggState::new(kind), "{kind:?} resets exactly at empty");
        }
    }
}
