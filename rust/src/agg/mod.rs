//! Aggregation functions with **incremental add/evict** semantics.
//!
//! Real sliding windows (paper §2) re-evaluate on every event arrival, so
//! aggregations must support both directions: `add` when an event enters
//! the window (tail iterator) and `evict` when it leaves (head iterator).
//! Invertible aggregates (count/sum/avg/variance) are O(1) both ways;
//! min/max use a monotonic deque keyed by event sequence number (amortized
//! O(1), exact); distinct-count keeps an exact value→multiplicity map;
//! ANOMALY_SCORE keeps Welford online mean/variance (forward and reverse
//! updates) and surfaces the z-score of the newest observation with
//! configurable severity bands (3σ/4σ/5σ by default).
//!
//! ## Batch kernels
//!
//! The scalar [`AggState::add`]/[`AggState::evict`] pair stays the
//! semantic reference, but the evaluation hot path applies whole **runs**
//! of updates at once through [`kernel`]: the plan gathers each batch's
//! `(seq, value, raw_hash)` rows into reusable per-(metric, slot)
//! columnar buffers and the kernels sweep them with tight slice loops —
//! the enum dispatch, slot bookkeeping and per-row value computation are
//! hoisted out of the loop. Kernels accumulate **in row order** (no
//! reassociation), so the resulting states and reply values are
//! bit-identical to the scalar path; `rust/tests/batch_equivalence.rs`
//! referees that contract.
//!
//! States serialize to compact bytes for the kvstore-backed state store
//! (paper §3.3.2: aggregation states persisted in RocksDB). The codec is
//! tag-versioned: new kinds append tags, old tags decode unchanged.

pub mod kernel;
mod state;

pub use state::{AggState, Welford, DEFAULT_BANDS};

use crate::error::{Error, Result};
use crate::event::ValueRef;
use crate::util::hash;

/// Supported aggregation functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggKind {
    /// `COUNT(*)` — number of events in the window.
    Count,
    /// `SUM(field)`.
    Sum,
    /// `AVG(field)`.
    Avg,
    /// `MIN(field)` (exact, monotonic-deque backed).
    Min,
    /// `MAX(field)` (exact, monotonic-deque backed).
    Max,
    /// Population standard deviation of `field`.
    StdDev,
    /// Exact number of distinct values of `field` in the window.
    CountDistinct,
    /// Online z-score of the newest observation against the window's
    /// Welford mean/variance (streaming anomaly detection).
    AnomalyScore,
}

impl AggKind {
    /// Stable tag for serialization.
    pub fn tag(self) -> u8 {
        match self {
            AggKind::Count => 0,
            AggKind::Sum => 1,
            AggKind::Avg => 2,
            AggKind::Min => 3,
            AggKind::Max => 4,
            AggKind::StdDev => 5,
            AggKind::CountDistinct => 6,
            AggKind::AnomalyScore => 7,
        }
    }

    /// Inverse of [`AggKind::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => AggKind::Count,
            1 => AggKind::Sum,
            2 => AggKind::Avg,
            3 => AggKind::Min,
            4 => AggKind::Max,
            5 => AggKind::StdDev,
            6 => AggKind::CountDistinct,
            7 => AggKind::AnomalyScore,
            t => return Err(Error::corrupt(format!("unknown agg tag {t}"))),
        })
    }

    /// Parse from query-language name.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "count" => AggKind::Count,
            "sum" => AggKind::Sum,
            "avg" | "mean" => AggKind::Avg,
            "min" => AggKind::Min,
            "max" => AggKind::Max,
            "stddev" | "std" => AggKind::StdDev,
            "count_distinct" | "distinct" => AggKind::CountDistinct,
            "anomaly_score" | "anomaly" => AggKind::AnomalyScore,
            other => return Err(Error::invalid(format!("unknown aggregation '{other}'"))),
        })
    }

    /// True if the function needs a field argument (`COUNT(*)` does not).
    pub fn needs_field(self) -> bool {
        !matches!(self, AggKind::Count)
    }

    /// Fresh empty state for this function.
    pub fn new_state(self) -> AggState {
        AggState::new(self)
    }
}

/// Resolve an aggregated field value into accumulator input:
/// `(value, raw_hash, include)`.
///
/// SQL semantics — `NULL` (and, for numeric aggregates, non-numeric)
/// values are excluded from field aggregates. `COUNT_DISTINCT` hashes
/// the value's key bytes through the tail of the caller's scratch buffer
/// (everything past `tail` is borrowed and truncated back), so no
/// per-event allocation happens on the hot path. Takes a borrowed
/// [`ValueRef`], so both owned events and reservoir views feed
/// accumulators through the same path.
#[inline]
pub fn resolve_input(
    kind: AggKind,
    v: ValueRef<'_>,
    scratch: &mut Vec<u8>,
    tail: usize,
) -> (f64, u64, bool) {
    match v {
        ValueRef::Null => (0.0, 0, false),
        _ => {
            if kind == AggKind::CountDistinct {
                v.key_bytes(scratch);
                let h = hash::hash64(&scratch[tail..]);
                scratch.truncate(tail);
                (0.0, h, true)
            } else {
                match v.as_f64() {
                    Some(x) => (x, 0, true),
                    None => (0.0, 0, false),
                }
            }
        }
    }
}

/// Severity band of a z-score: `0` = nominal, `1..=3` = number of
/// thresholds (3σ/4σ/5σ by default) that `|z|` clears.
#[inline]
pub fn severity(z: f64, bands: &[f64; 3]) -> u8 {
    bands.iter().filter(|b| z.abs() >= **b).count() as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip() {
        for k in [
            AggKind::Count,
            AggKind::Sum,
            AggKind::Avg,
            AggKind::Min,
            AggKind::Max,
            AggKind::StdDev,
            AggKind::CountDistinct,
            AggKind::AnomalyScore,
        ] {
            assert_eq!(AggKind::from_tag(k.tag()).unwrap(), k);
        }
        assert!(AggKind::from_tag(200).is_err());
    }

    #[test]
    fn parse_names() {
        assert_eq!(AggKind::parse("SUM").unwrap(), AggKind::Sum);
        assert_eq!(AggKind::parse("count").unwrap(), AggKind::Count);
        assert_eq!(AggKind::parse("mean").unwrap(), AggKind::Avg);
        assert_eq!(AggKind::parse("anomaly_score").unwrap(), AggKind::AnomalyScore);
        assert_eq!(AggKind::parse("ANOMALY").unwrap(), AggKind::AnomalyScore);
        assert!(AggKind::parse("median").is_err());
    }

    #[test]
    fn needs_field() {
        assert!(!AggKind::Count.needs_field());
        assert!(AggKind::Sum.needs_field());
        assert!(AggKind::AnomalyScore.needs_field());
    }

    #[test]
    fn severity_bands() {
        assert_eq!(severity(0.0, &DEFAULT_BANDS), 0);
        assert_eq!(severity(-3.2, &DEFAULT_BANDS), 1);
        assert_eq!(severity(4.0, &DEFAULT_BANDS), 2);
        assert_eq!(severity(-17.0, &DEFAULT_BANDS), 3);
        assert_eq!(severity(2.5, &[1.0, 2.0, 9.0]), 2, "custom bands");
    }
}
