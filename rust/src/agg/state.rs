//! Aggregation state: incremental updates + binary persistence.

use crate::agg::AggKind;
use crate::error::{Error, Result};
use crate::util::varint;
use std::collections::VecDeque;

/// Numeric moments shared by count/sum/avg/stddev.
///
/// Fields are crate-visible so [`crate::agg::kernel`] can run its batch
/// loops directly over the accumulators.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Moments {
    pub(crate) count: u64,
    pub(crate) sum: f64,
    pub(crate) sumsq: f64,
}

impl Moments {
    /// Aggregate value for `kind`. Shared by [`AggState::value`] and the
    /// kernel module's emitting batch loop so both paths compute
    /// bit-identical results.
    #[inline]
    pub(crate) fn value_of(&self, kind: AggKind) -> Option<f64> {
        match kind {
            AggKind::Count => Some(self.count as f64),
            AggKind::Sum => Some(self.sum),
            AggKind::Avg => {
                if self.count == 0 {
                    None
                } else {
                    Some(self.sum / self.count as f64)
                }
            }
            AggKind::StdDev => {
                if self.count == 0 {
                    None
                } else {
                    let mean = self.sum / self.count as f64;
                    let var = (self.sumsq / self.count as f64 - mean * mean).max(0.0);
                    Some(var.sqrt())
                }
            }
            _ => unreachable!("non-moment kind in Moments"),
        }
    }
}

/// Default ANOMALY_SCORE severity thresholds, in σ units.
pub const DEFAULT_BANDS: [f64; 3] = [3.0, 4.0, 5.0];

/// Welford online mean/variance for ANOMALY_SCORE: the window's running
/// moments plus the most recently arrived observation, surfaced as the
/// z-score `(last - mean) / stddev`.
#[derive(Debug, Clone, PartialEq)]
pub struct Welford {
    pub(crate) count: u64,
    pub(crate) mean: f64,
    pub(crate) m2: f64,
    /// Most recently added value — the observation being scored.
    pub(crate) last: f64,
    /// Severity thresholds in σ units (3σ/4σ/5σ by default).
    pub(crate) bands: [f64; 3],
}

impl Default for Welford {
    fn default() -> Welford {
        Welford {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            last: 0.0,
            bands: DEFAULT_BANDS,
        }
    }
}

impl Welford {
    /// Forward Welford update: `x` enters the window.
    #[inline]
    pub(crate) fn add(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
        self.last = x;
    }

    /// Reverse Welford update: `x` leaves the window. At the empty point
    /// the moments reset exactly, cancelling accumulated float drift —
    /// the same contract [`Moments`] eviction keeps.
    #[inline]
    pub(crate) fn evict(&mut self, x: f64) {
        debug_assert!(self.count > 0, "evict from empty anomaly window");
        self.count = self.count.saturating_sub(1);
        if self.count == 0 {
            self.mean = 0.0;
            self.m2 = 0.0;
        } else {
            let old_mean = self.mean;
            self.mean = (old_mean * (self.count + 1) as f64 - x) / self.count as f64;
            self.m2 -= (x - old_mean) * (x - self.mean);
        }
    }

    /// Current z-score of the last observation (`None` on an empty
    /// window; `0.0` when the window has no spread).
    #[inline]
    pub(crate) fn value(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        // reverse updates can leave m2 a hair below zero: clamp
        let var = (self.m2 / self.count as f64).max(0.0);
        if var <= 0.0 {
            return Some(0.0);
        }
        Some((self.last - self.mean) / var.sqrt())
    }

    /// Severity band of the current score: `0` = nominal, `1..=3` = the
    /// number of thresholds (3σ/4σ/5σ by default) the |z| clears.
    pub fn severity(&self) -> Option<u8> {
        let z = self.value()?;
        Some(crate::agg::severity(z, &self.bands))
    }
}

/// Monotonic deque entry for min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct MonoEntry {
    pub(crate) seq: u64,
    pub(crate) value: f64,
}

/// Serializable, incrementally-updatable aggregation state.
#[derive(Debug, Clone, PartialEq)]
pub enum AggState {
    /// count/sum/avg/stddev share the moments representation.
    Moments(AggKind, Moments),
    /// min (`is_min = true`) / max: monotonic deque over (seq, value).
    Extremum {
        /// True for MIN, false for MAX.
        is_min: bool,
        /// Candidate extrema in seq order; front is the current answer.
        deque: VecDeque<MonoEntry>,
    },
    /// Exact distinct count: value-hash → multiplicity.
    ///
    /// Keyed by the 64-bit hash of the value's key-bytes; a hash collision
    /// would conflate two values — acceptable at fraud-profile
    /// cardinalities (~1e5 ⇒ collision odds ~1e-9).
    Distinct(std::collections::BTreeMap<u64, u32>),
    /// ANOMALY_SCORE: Welford online mean/variance surfacing the z-score
    /// of the most recent observation, with severity bands.
    Anomaly(Welford),
}

impl AggState {
    /// Empty state for `kind`.
    pub fn new(kind: AggKind) -> AggState {
        match kind {
            AggKind::Count | AggKind::Sum | AggKind::Avg | AggKind::StdDev => {
                AggState::Moments(kind, Moments::default())
            }
            AggKind::Min => AggState::Extremum {
                is_min: true,
                deque: VecDeque::new(),
            },
            AggKind::Max => AggState::Extremum {
                is_min: false,
                deque: VecDeque::new(),
            },
            AggKind::CountDistinct => AggState::Distinct(Default::default()),
            AggKind::AnomalyScore => AggState::Anomaly(Welford::default()),
        }
    }

    /// Empty state for `kind` with explicit ANOMALY_SCORE severity bands
    /// (other kinds ignore `bands`).
    pub fn new_banded(kind: AggKind, bands: [f64; 3]) -> AggState {
        match kind {
            AggKind::AnomalyScore => AggState::Anomaly(Welford {
                bands,
                ..Welford::default()
            }),
            _ => AggState::new(kind),
        }
    }

    /// Event enters the window. `seq` is the reservoir sequence number
    /// (drives min/max eviction); `value` is the aggregated field (`0.0`
    /// for COUNT/COUNT_DISTINCT's unused slot; distinct uses `raw_hash`).
    pub fn add(&mut self, seq: u64, value: f64, raw_hash: u64) {
        match self {
            AggState::Moments(_, m) => {
                m.count += 1;
                m.sum += value;
                m.sumsq += value * value;
            }
            AggState::Extremum { is_min, deque } => {
                let keep = |cand: f64, new: f64| {
                    if *is_min {
                        cand < new
                    } else {
                        cand > new
                    }
                };
                while let Some(back) = deque.back() {
                    if keep(back.value, value) {
                        break;
                    }
                    deque.pop_back();
                }
                deque.push_back(MonoEntry { seq, value });
            }
            AggState::Distinct(map) => {
                *map.entry(raw_hash).or_insert(0) += 1;
            }
            AggState::Anomaly(w) => w.add(value),
        }
    }

    /// Event leaves the window (same arguments it was added with; events
    /// expire in seq order).
    pub fn evict(&mut self, seq: u64, value: f64, raw_hash: u64) {
        match self {
            AggState::Moments(_, m) => {
                debug_assert!(m.count > 0, "evict from empty aggregation");
                m.count = m.count.saturating_sub(1);
                m.sum -= value;
                m.sumsq -= value * value;
                if m.count == 0 {
                    // cancel accumulated float drift at the empty point
                    m.sum = 0.0;
                    m.sumsq = 0.0;
                }
            }
            AggState::Extremum { deque, .. } => {
                if let Some(front) = deque.front() {
                    if front.seq == seq {
                        deque.pop_front();
                    }
                }
            }
            AggState::Distinct(map) => {
                if let Some(c) = map.get_mut(&raw_hash) {
                    // a hash evicted more times than added (corrupt replay
                    // input) must not wrap to ~4e9 distinct in release
                    debug_assert!(*c > 0, "distinct evict below zero multiplicity");
                    *c = c.saturating_sub(1);
                    if *c == 0 {
                        map.remove(&raw_hash);
                    }
                }
            }
            AggState::Anomaly(w) => w.evict(value),
        }
    }

    /// Current aggregate value (`None` when the window is empty and the
    /// function has no identity, e.g. MIN/AVG of nothing).
    pub fn value(&self) -> Option<f64> {
        match self {
            AggState::Moments(kind, m) => m.value_of(*kind),
            AggState::Extremum { deque, .. } => deque.front().map(|e| e.value),
            AggState::Distinct(map) => Some(map.len() as f64),
            AggState::Anomaly(w) => w.value(),
        }
    }

    /// Number of live entries the state tracks (observability).
    pub fn footprint(&self) -> usize {
        match self {
            AggState::Moments(..) => 1,
            AggState::Extremum { deque, .. } => deque.len(),
            AggState::Distinct(map) => map.len(),
            AggState::Anomaly(..) => 1,
        }
    }

    /// Serialize for the state store.
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            AggState::Moments(kind, m) => {
                out.push(kind.tag());
                varint::write_u64(out, m.count);
                out.extend_from_slice(&m.sum.to_bits().to_le_bytes());
                out.extend_from_slice(&m.sumsq.to_bits().to_le_bytes());
            }
            AggState::Extremum { is_min, deque } => {
                out.push(if *is_min {
                    AggKind::Min.tag()
                } else {
                    AggKind::Max.tag()
                });
                varint::write_u64(out, deque.len() as u64);
                for e in deque {
                    varint::write_u64(out, e.seq);
                    out.extend_from_slice(&e.value.to_bits().to_le_bytes());
                }
            }
            AggState::Distinct(map) => {
                out.push(AggKind::CountDistinct.tag());
                varint::write_u64(out, map.len() as u64);
                for (h, c) in map {
                    varint::write_u64(out, *h);
                    varint::write_u32(out, *c);
                }
            }
            AggState::Anomaly(w) => {
                out.push(AggKind::AnomalyScore.tag());
                varint::write_u64(out, w.count);
                out.extend_from_slice(&w.mean.to_bits().to_le_bytes());
                out.extend_from_slice(&w.m2.to_bits().to_le_bytes());
                out.extend_from_slice(&w.last.to_bits().to_le_bytes());
                for b in &w.bands {
                    out.extend_from_slice(&b.to_bits().to_le_bytes());
                }
            }
        }
    }

    /// Deserialize a state previously written by [`AggState::encode`].
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<AggState> {
        let tag = *buf
            .get(*pos)
            .ok_or_else(|| Error::corrupt("agg state: empty"))?;
        *pos += 1;
        let kind = AggKind::from_tag(tag)?;
        let read_f64 = |buf: &[u8], pos: &mut usize| -> Result<f64> {
            let end = *pos + 8;
            if end > buf.len() {
                return Err(Error::corrupt("agg state: truncated f64"));
            }
            let v = f64::from_bits(u64::from_le_bytes(buf[*pos..end].try_into().unwrap()));
            *pos = end;
            Ok(v)
        };
        Ok(match kind {
            AggKind::Count | AggKind::Sum | AggKind::Avg | AggKind::StdDev => {
                let count = varint::read_u64(buf, pos)?;
                let sum = read_f64(buf, pos)?;
                let sumsq = read_f64(buf, pos)?;
                AggState::Moments(kind, Moments { count, sum, sumsq })
            }
            AggKind::Min | AggKind::Max => {
                let n = varint::read_u64(buf, pos)? as usize;
                let mut deque = VecDeque::with_capacity(n);
                for _ in 0..n {
                    let seq = varint::read_u64(buf, pos)?;
                    let value = read_f64(buf, pos)?;
                    deque.push_back(MonoEntry { seq, value });
                }
                AggState::Extremum {
                    is_min: kind == AggKind::Min,
                    deque,
                }
            }
            AggKind::CountDistinct => {
                let n = varint::read_u64(buf, pos)? as usize;
                let mut map = std::collections::BTreeMap::new();
                for _ in 0..n {
                    let h = varint::read_u64(buf, pos)?;
                    let c = varint::read_u32(buf, pos)?;
                    map.insert(h, c);
                }
                AggState::Distinct(map)
            }
            AggKind::AnomalyScore => {
                let count = varint::read_u64(buf, pos)?;
                let mean = read_f64(buf, pos)?;
                let m2 = read_f64(buf, pos)?;
                let last = read_f64(buf, pos)?;
                let mut bands = [0.0; 3];
                for b in &mut bands {
                    *b = read_f64(buf, pos)?;
                }
                AggState::Anomaly(Welford {
                    count,
                    mean,
                    m2,
                    last,
                    bands,
                })
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Rng;

    fn drive(kind: AggKind, ops: &[(bool, u64, f64)]) -> AggState {
        // ops: (is_add, seq, value)
        let mut st = AggState::new(kind);
        for (add, seq, v) in ops {
            if *add {
                st.add(*seq, *v, (*v).to_bits());
            } else {
                st.evict(*seq, *v, (*v).to_bits());
            }
        }
        st
    }

    #[test]
    fn count_add_evict() {
        let st = drive(
            AggKind::Count,
            &[(true, 0, 0.0), (true, 1, 0.0), (false, 0, 0.0)],
        );
        assert_eq!(st.value(), Some(1.0));
    }

    #[test]
    fn sum_and_avg() {
        let mut st = AggState::new(AggKind::Sum);
        st.add(0, 10.0, 0);
        st.add(1, 20.0, 0);
        assert_eq!(st.value(), Some(30.0));
        st.evict(0, 10.0, 0);
        assert_eq!(st.value(), Some(20.0));

        let mut st = AggState::new(AggKind::Avg);
        assert_eq!(st.value(), None, "avg of empty is undefined");
        st.add(0, 10.0, 0);
        st.add(1, 20.0, 0);
        assert_eq!(st.value(), Some(15.0));
    }

    #[test]
    fn stddev_matches_direct() {
        let vals = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut st = AggState::new(AggKind::StdDev);
        for (i, v) in vals.iter().enumerate() {
            st.add(i as u64, *v, 0);
        }
        assert!((st.value().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn min_max_sliding_behaviour() {
        // window of values with eviction in order: classic deque test
        let mut mx = AggState::new(AggKind::Max);
        let vals = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        for (i, v) in vals.iter().enumerate() {
            mx.add(i as u64, *v, 0);
        }
        assert_eq!(mx.value(), Some(9.0));
        // evict up to and including seq 5 (value 9.0)
        for (i, v) in vals.iter().enumerate().take(6) {
            mx.evict(i as u64, *v, 0);
        }
        assert_eq!(mx.value(), Some(6.0), "max of remaining [2,6]");

        let mut mn = AggState::new(AggKind::Min);
        for (i, v) in vals.iter().enumerate() {
            mn.add(i as u64, *v, 0);
        }
        assert_eq!(mn.value(), Some(1.0));
        for (i, v) in vals.iter().enumerate().take(4) {
            mn.evict(i as u64, *v, 0);
        }
        assert_eq!(mn.value(), Some(2.0), "min of [5,9,2,6]");
    }

    #[test]
    fn distinct_counts_unique_values() {
        let mut st = AggState::new(AggKind::CountDistinct);
        for (i, h) in [10u64, 20, 10, 30, 20, 10].iter().enumerate() {
            st.add(i as u64, 0.0, *h);
        }
        assert_eq!(st.value(), Some(3.0));
        // evict one of the three 10s: still distinct 3
        st.evict(0, 0.0, 10);
        assert_eq!(st.value(), Some(3.0));
        st.evict(2, 0.0, 10);
        st.evict(5, 0.0, 10);
        assert_eq!(st.value(), Some(2.0), "all 10s gone");
    }

    #[test]
    fn empty_after_full_eviction() {
        for kind in [
            AggKind::Count,
            AggKind::Sum,
            AggKind::Avg,
            AggKind::Min,
            AggKind::Max,
            AggKind::StdDev,
            AggKind::CountDistinct,
            AggKind::AnomalyScore,
        ] {
            let mut st = AggState::new(kind);
            st.add(0, 5.0, 1);
            st.evict(0, 5.0, 1);
            match kind {
                AggKind::Count | AggKind::CountDistinct => assert_eq!(st.value(), Some(0.0)),
                AggKind::Sum => assert_eq!(st.value(), Some(0.0)),
                _ => assert_eq!(st.value(), None, "{kind:?}"),
            }
            assert!(st.footprint() <= 1);
        }
    }

    #[test]
    fn encode_decode_roundtrip_all_kinds() {
        let mut rng = Rng::new(77);
        for kind in [
            AggKind::Count,
            AggKind::Sum,
            AggKind::Avg,
            AggKind::Min,
            AggKind::Max,
            AggKind::StdDev,
            AggKind::CountDistinct,
            AggKind::AnomalyScore,
        ] {
            let mut st = AggState::new(kind);
            for i in 0..50u64 {
                st.add(i, rng.next_f64() * 100.0, rng.next_below(10));
            }
            let mut buf = Vec::new();
            st.encode(&mut buf);
            let mut pos = 0;
            let back = AggState::decode(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len());
            assert_eq!(back, st, "{kind:?}");
        }
    }

    #[test]
    fn decode_garbage_errors() {
        let mut pos = 0;
        assert!(AggState::decode(&[], &mut pos).is_err());
        let mut pos = 0;
        assert!(AggState::decode(&[99], &mut pos).is_err());
        let mut pos = 0;
        assert!(AggState::decode(&[1, 5], &mut pos).is_err(), "truncated sum");
    }

    /// Property: add/evict over a sliding window ≡ recomputing the
    /// aggregate from scratch over the live suffix.
    #[test]
    fn property_incremental_equals_recompute() {
        check(
            "agg incremental == recompute",
            80,
            |rng| {
                let n = rng.index(60) + 2;
                let w = rng.index(n) + 1;
                let vals: Vec<u64> = (0..n).map(|_| rng.next_below(100)).collect();
                (vals, w)
            },
            |(vals, w)| {
                if *w == 0 || vals.is_empty() {
                    return Ok(()); // degenerate shrink candidates
                }
                for kind in [
                    AggKind::Count,
                    AggKind::Sum,
                    AggKind::Avg,
                    AggKind::Min,
                    AggKind::Max,
                    AggKind::StdDev,
                    AggKind::CountDistinct,
                    AggKind::AnomalyScore,
                ] {
                    let mut st = AggState::new(kind);
                    for (i, v) in vals.iter().enumerate() {
                        let vf = *v as f64;
                        st.add(i as u64, vf, *v);
                        if i >= *w {
                            let old = vals[i - w] as f64;
                            st.evict((i - w) as u64, old, vals[i - w]);
                        }
                        // recompute over live window vals[i-w+1 ..= i]
                        let lo = i.saturating_sub(w - 1);
                        let live: Vec<f64> = vals[lo..=i].iter().map(|v| *v as f64).collect();
                        let expect = match kind {
                            AggKind::Count => Some(live.len() as f64),
                            AggKind::Sum => Some(live.iter().sum()),
                            AggKind::Avg => {
                                Some(live.iter().sum::<f64>() / live.len() as f64)
                            }
                            AggKind::Min => live.iter().copied().reduce(f64::min),
                            AggKind::Max => live.iter().copied().reduce(f64::max),
                            AggKind::StdDev => {
                                let mean = live.iter().sum::<f64>() / live.len() as f64;
                                let var = live.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                                    / live.len() as f64;
                                Some(var.sqrt())
                            }
                            AggKind::CountDistinct => {
                                let mut set = std::collections::HashSet::new();
                                for v in &vals[lo..=i] {
                                    set.insert(*v);
                                }
                                Some(set.len() as f64)
                            }
                            AggKind::AnomalyScore => {
                                // batch oracle: z-score of the newest
                                // observation against the live window's
                                // population mean/variance
                                let mean = live.iter().sum::<f64>() / live.len() as f64;
                                let var = live.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                                    / live.len() as f64;
                                if var <= 0.0 {
                                    Some(0.0)
                                } else {
                                    Some((vals[i] as f64 - mean) / var.sqrt())
                                }
                            }
                        };
                        let got = st.value();
                        // z-scores get a looser bound: reverse Welford near
                        // zero spread amplifies rounding in the ratio
                        let tol = if kind == AggKind::AnomalyScore { 1e-4 } else { 1e-6 };
                        let ok = match (got, expect) {
                            (Some(a), Some(b)) => (a - b).abs() < tol,
                            (None, None) => true,
                            _ => false,
                        };
                        if !ok {
                            return Err(format!(
                                "{kind:?} at i={i}: incremental={got:?} recompute={expect:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn anomaly_scores_outliers() {
        let mut st = AggState::new(AggKind::AnomalyScore);
        // steady baseline around 10 with a little spread
        for (i, v) in [10.0, 10.5, 9.5, 10.0, 10.2, 9.8, 10.1, 9.9].iter().enumerate() {
            st.add(i as u64, *v, 0);
            assert!(st.value().unwrap().abs() < 3.0, "baseline is nominal");
        }
        st.add(8, 40.0, 0);
        let z = st.value().unwrap();
        assert!(z > 2.5, "spike scores high, got {z}");
        let AggState::Anomaly(w) = &st else { panic!() };
        assert!(w.severity().unwrap() >= 1, "spike clears at least one band");
    }

    #[test]
    fn anomaly_constant_window_scores_zero() {
        let mut st = AggState::new(AggKind::AnomalyScore);
        for i in 0..10u64 {
            st.add(i, 42.0, 0);
        }
        assert_eq!(st.value(), Some(0.0), "no spread ⇒ nominal");
    }

    /// Drift cancellation: a fully-evicted anomaly window resets its
    /// moments **exactly**, so the encoded state is bitwise identical to a
    /// fresh one and later windows start clean.
    #[test]
    fn anomaly_empty_window_cancels_drift() {
        let mut rng = Rng::new(0xD21F7);
        let mut st = AggState::new_banded(AggKind::AnomalyScore, [2.0, 3.0, 4.0]);
        let fresh = st.clone();
        for _ in 0..20 {
            let vals: Vec<f64> = (0..rng.index(30) + 1)
                .map(|_| rng.next_f64() * 1000.0 - 300.0)
                .collect();
            for (i, v) in vals.iter().enumerate() {
                st.add(i as u64, *v, 0);
            }
            for (i, v) in vals.iter().enumerate() {
                st.evict(i as u64, *v, 0);
            }
            assert_eq!(st.value(), None);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            st.encode(&mut a);
            fresh.encode(&mut b);
            assert_eq!(a, b, "empty window must encode byte-identical to fresh");
        }
    }

    /// Tag-7 state codec: non-default bands and mid-window moments
    /// roundtrip exactly; old tags are untouched by the new kind.
    #[test]
    fn anomaly_codec_roundtrip_with_bands() {
        let mut st = AggState::new_banded(AggKind::AnomalyScore, [1.5, 2.5, 6.0]);
        for (i, v) in [3.25, -7.5, 11.0, 0.125].iter().enumerate() {
            st.add(i as u64, *v, 0);
        }
        let mut buf = Vec::new();
        st.encode(&mut buf);
        assert_eq!(buf[0], AggKind::AnomalyScore.tag());
        let mut pos = 0;
        let back = AggState::decode(&buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        assert_eq!(back, st);
        let AggState::Anomaly(w) = back else { panic!() };
        assert_eq!(w.bands, [1.5, 2.5, 6.0]);
        // truncated tag-7 payloads are rejected, not misparsed
        let mut pos = 0;
        assert!(AggState::decode(&buf[..buf.len() - 3], &mut pos).is_err());
    }

    /// Regression: a hash evicted more times than added (corrupt replay
    /// input — only reachable through a decoded zero-multiplicity entry)
    /// must not wrap the count to ~4e9 in release builds.
    #[test]
    fn distinct_overeviction_saturates() {
        let mut map = std::collections::BTreeMap::new();
        map.insert(7u64, 0u32); // corrupt: zero multiplicity
        let mut st = AggState::Distinct(map);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            st.evict(0, 0.0, 7);
        }));
        if cfg!(debug_assertions) {
            assert!(caught.is_err(), "debug build asserts on over-eviction");
        } else {
            caught.unwrap();
            assert_eq!(st.value(), Some(0.0), "saturated, not wrapped to ~4e9");
            assert_eq!(st.footprint(), 0, "zeroed entry is dropped");
        }
    }
}
