//! Shared experiment driver used by `rust/benches/*`: builds a
//! single-node Railgun stack, injects the synthetic fraud workload at a
//! virtual rate, and records coordinated-omission-corrected latencies.

use crate::config::{EngineConfig, StreamDef};
use crate::coordinator::Node;
use crate::error::Result;
use crate::mlog::{Broker, BrokerConfig};
use crate::plan::MetricSpec;
use crate::util::bench::Series;
use crate::util::tmp::TempDir;
use crate::workload::{payments_schema, CoInjector, FraudGenerator, WorkloadConfig};
use std::time::Duration;

/// Experiment knobs for one Railgun end-to-end run.
pub struct RailgunRun {
    /// Metrics to register (all routed by `card`).
    pub metrics: Vec<MetricSpec>,
    /// Events to drive.
    pub events: u64,
    /// Offered rate (ev/s) for CO correction (the paper uses 500).
    pub rate_eps: f64,
    /// Event-time spacing in milliseconds (decouples the event-time span
    /// from wall-clock so long windows are exercisable — DESIGN.md §1).
    pub event_spacing_ms: i64,
    /// Workload shape.
    pub workload: WorkloadConfig,
    /// Engine config overrides applied to the testing defaults.
    pub tune: fn(&mut EngineConfig),
    /// Warmup events (recorded separately, excluded from the series).
    pub warmup: u64,
}

impl RailgunRun {
    /// Defaults: Q1-ish workload at the paper's 500 ev/s.
    pub fn new(metrics: Vec<MetricSpec>, events: u64) -> RailgunRun {
        RailgunRun {
            metrics,
            events,
            rate_eps: 500.0,
            event_spacing_ms: 2,
            workload: WorkloadConfig::default(),
            tune: |_| {},
            warmup: 0,
        }
    }

    /// Execute and return the labelled series.
    pub fn run(self, label: &str) -> Result<Series> {
        let tmp = TempDir::new("bench_run");
        let broker = Broker::open(BrokerConfig::in_memory())?;
        let mut cfg = EngineConfig {
            processor_units: 1,
            partitions_per_topic: 2,
            ..EngineConfig::new(tmp.path().to_path_buf())
        };
        (self.tune)(&mut cfg);
        let node = Node::start("bench", cfg, broker)?;
        node.register_stream(StreamDef {
            name: "payments".into(),
            schema: payments_schema(),
            entities: vec!["card".into()],
            metrics: self.metrics,
        })?;
        let mut collector = node.reply_collector()?;
        let mut generator = FraudGenerator::new(self.workload);
        let mut injector = CoInjector::new(self.rate_eps);

        let base_ts = 1_600_000_000_000i64;
        for i in 0..(self.warmup + self.events) {
            let ts = base_ts + i as i64 * self.event_spacing_ms;
            let event = generator.next_event(ts);
            let recording = i >= self.warmup;
            let work = || -> Result<()> {
                let receipt = node.frontend().ingest("payments", event)?;
                collector.await_event(
                    receipt.ingest_id,
                    receipt.fanout,
                    Duration::from_secs(60),
                )?;
                Ok(())
            };
            if recording {
                injector.observe(work)?;
            } else {
                work()?;
            }
        }
        let report = injector.report();
        let mut series = Series::new(label);
        series.hist = injector.hist.clone();
        series.throughput_eps = report.capacity_eps;
        series.note("kept_up", report.kept_up);
        series.note("service_p50_us", injector.service_hist.quantile(0.5) / 1000);
        node.shutdown(true);
        Ok(series)
    }
}
