//! Synthetic fraud workload (DESIGN.md §1 substitution for the paper's
//! proprietary client dataset) + the latency-measuring injector.
//!
//! The dataset's role in the paper is to provide "real-world dictionary
//! cardinality for aggregation states" (§4.1): the generator draws cards
//! and merchants from Zipf distributions with realistic cardinalities and
//! log-normal transaction amounts, so the state-store population and
//! group-by skew behave like production traffic.

pub mod driver;
mod generator;
mod injector;

pub use generator::{payments_schema, FraudGenerator, WorkloadConfig};
pub use injector::{ArrivalSchedule, CoInjector, InjectorReport};
