//! Fraud-transaction generator.

use crate::event::{Event, FieldType, Schema, SchemaRef, Value};
use crate::util::clock::TimestampMs;
use crate::util::rng::{Rng, Zipf};

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Number of distinct cards (paper-scale default 50k).
    pub cards: usize,
    /// Number of distinct merchants.
    pub merchants: usize,
    /// Zipf skew for card popularity (1.0 ≈ web-traffic skew).
    pub card_skew: f64,
    /// Zipf skew for merchant popularity.
    pub merchant_skew: f64,
    /// Log-normal μ for amounts (exp(μ) ≈ median amount).
    pub amount_mu: f64,
    /// Log-normal σ for amounts.
    pub amount_sigma: f64,
    /// Fraction of card-not-present transactions.
    pub cnp_rate: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            cards: 50_000,
            merchants: 2_000,
            card_skew: 1.05,
            merchant_skew: 1.1,
            amount_mu: 3.2,  // median ≈ €24.5
            amount_sigma: 1.2,
            cnp_rate: 0.25,
            seed: 0xF4A0D,
        }
    }
}

/// The canonical `payments` stream schema used across examples/benches.
pub fn payments_schema() -> SchemaRef {
    Schema::of(&[
        ("card", FieldType::Str),
        ("merchant", FieldType::Str),
        ("amount", FieldType::F64),
        ("cnp", FieldType::Bool),
    ])
    .expect("static schema is valid")
}

/// Deterministic synthetic payment stream.
pub struct FraudGenerator {
    rng: Rng,
    cards: Zipf,
    merchants: Zipf,
    cfg: WorkloadConfig,
}

impl FraudGenerator {
    /// Build from config (Zipf CDF precomputation is O(cards)).
    pub fn new(cfg: WorkloadConfig) -> FraudGenerator {
        FraudGenerator {
            rng: Rng::new(cfg.seed),
            cards: Zipf::new(cfg.cards, cfg.card_skew),
            merchants: Zipf::new(cfg.merchants, cfg.merchant_skew),
            cfg,
        }
    }

    /// Generate the next event at `ts`.
    pub fn next_event(&mut self, ts: TimestampMs) -> Event {
        let card = self.cards.sample(&mut self.rng);
        let merchant = self.merchants.sample(&mut self.rng);
        let amount = self
            .rng
            .next_lognormal(self.cfg.amount_mu, self.cfg.amount_sigma);
        let cnp = self.rng.chance(self.cfg.cnp_rate);
        Event::new(
            ts,
            vec![
                Value::Str(format!("card_{card:06}")),
                Value::Str(format!("m_{merchant:05}")),
                Value::F64((amount * 100.0).round() / 100.0),
                Value::Bool(cnp),
            ],
        )
    }

    /// Generate a burst of `n` events from the *same* card at `ts`
    /// (adversarial cadence — the paper's §2.1 attack scenario).
    pub fn attack_burst(&mut self, ts: TimestampMs, n: usize, spacing_ms: i64) -> Vec<Event> {
        let card = format!("card_attacker");
        let merchant = self.merchants.sample(&mut self.rng);
        (0..n)
            .map(|i| {
                Event::new(
                    ts + i as i64 * spacing_ms,
                    vec![
                        Value::Str(card.clone()),
                        Value::Str(format!("m_{merchant:05}")),
                        Value::F64(9.99),
                        Value::Bool(true),
                    ],
                )
            })
            .collect()
    }

    /// Generate a burst of `n` events from the *same* card at `ts` whose
    /// amounts sit far out on the log-normal tail (≈4σ above the
    /// configured μ in log space) — the stimulus an `ANOMALY_SCORE`
    /// metric over `amount` is meant to flag.
    pub fn anomaly_burst(&mut self, ts: TimestampMs, n: usize, spacing_ms: i64) -> Vec<Event> {
        let card = "card_anomaly".to_string();
        let merchant = self.merchants.sample(&mut self.rng);
        let mu = self.cfg.amount_mu + 4.0 * self.cfg.amount_sigma;
        let sigma = self.cfg.amount_sigma / 4.0;
        (0..n)
            .map(|i| {
                let amount = self.rng.next_lognormal(mu, sigma);
                Event::new(
                    ts + i as i64 * spacing_ms,
                    vec![
                        Value::Str(card.clone()),
                        Value::Str(format!("m_{merchant:05}")),
                        Value::F64((amount * 100.0).round() / 100.0),
                        Value::Bool(true),
                    ],
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn small() -> WorkloadConfig {
        WorkloadConfig {
            cards: 1000,
            merchants: 100,
            ..WorkloadConfig::default()
        }
    }

    #[test]
    fn events_validate_against_schema() {
        let schema = payments_schema();
        let mut g = FraudGenerator::new(small());
        for i in 0..100 {
            let e = g.next_event(i * 1000);
            schema.validate(&e).unwrap();
            assert_eq!(e.timestamp, i * 1000);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = FraudGenerator::new(small());
        let mut b = FraudGenerator::new(small());
        for i in 0..50 {
            assert_eq!(a.next_event(i), b.next_event(i));
        }
    }

    #[test]
    fn card_popularity_is_skewed() {
        let mut g = FraudGenerator::new(small());
        let mut counts: std::collections::HashMap<String, u32> = Default::default();
        for i in 0..20_000 {
            let e = g.next_event(i);
            *counts
                .entry(e.values[0].as_str().unwrap().to_string())
                .or_default() += 1;
        }
        let mut v: Vec<u32> = counts.values().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        assert!(v[0] > 200, "head card is hot (zipf): {}", v[0]);
        assert!(counts.len() > 300, "long tail is populated: {}", counts.len());
    }

    #[test]
    fn amounts_are_positive_and_dispersed() {
        let mut g = FraudGenerator::new(small());
        let mut distinct = HashSet::new();
        for i in 0..1000 {
            let a = g.next_event(i).values[2].as_f64().unwrap();
            assert!(a > 0.0);
            distinct.insert((a * 100.0) as i64);
        }
        assert!(distinct.len() > 500, "amounts vary: {}", distinct.len());
    }

    #[test]
    fn attack_burst_is_single_card_with_cadence() {
        let mut g = FraudGenerator::new(small());
        let burst = g.attack_burst(1000, 5, 60_000);
        assert_eq!(burst.len(), 5);
        let cards: HashSet<&str> = burst.iter().map(|e| e.values[0].as_str().unwrap()).collect();
        assert_eq!(cards.len(), 1);
        assert_eq!(burst[4].timestamp - burst[0].timestamp, 4 * 60_000);
    }

    #[test]
    fn anomaly_burst_amounts_are_tail_outliers() {
        let mut g = FraudGenerator::new(small());
        // empirical median of the baseline amount distribution ≈ exp(μ)
        let mut baseline: Vec<f64> = (0..1001)
            .map(|i| g.next_event(i).values[2].as_f64().unwrap())
            .collect();
        baseline.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let median = baseline[baseline.len() / 2];
        let burst = g.anomaly_burst(10_000, 8, 1000);
        assert_eq!(burst.len(), 8);
        let cards: HashSet<&str> = burst.iter().map(|e| e.values[0].as_str().unwrap()).collect();
        assert_eq!(cards.len(), 1, "single card");
        let schema = payments_schema();
        for e in &burst {
            schema.validate(e).unwrap();
            let a = e.values[2].as_f64().unwrap();
            // burst amounts live ≈4σ up the log-normal tail: far above
            // the body of the baseline distribution
            assert!(a > 20.0 * median, "outlier {a} vs baseline median {median}");
        }
    }
}
