//! Latency injection with **coordinated-omission correction** (paper
//! §4.1, cite [14]).
//!
//! The paper injects at a sustained 500 ev/s and corrects latencies for
//! coordinated omission. On this testbed we cannot spend 35 wall-clock
//! minutes per sweep point, so the injector runs the engine at full speed
//! while *accounting* in the open-loop arrival model:
//!
//! ```text
//! intended_i  = i / rate                 (arrivals are a fixed cadence)
//! start_i     = max(intended_i, done_{i-1})   (engine is sequential)
//! done_i      = start_i + service_i      (service_i measured per event)
//! latency_i   = done_i − intended_i      (queueing + service)
//! ```
//!
//! This is exactly the correction [14] prescribes: an engine slower than
//! the interarrival gap accumulates queueing delay and its corrected tail
//! explodes ("unable to keep up", Figure 5); an engine faster than the
//! gap reports pure service latency. The model is conservative for
//! Railgun (no pipelining credit) and exact for single-threaded task
//! processors.

use crate::util::hist::Histogram;
use std::time::Instant;

/// Fixed-cadence arrival schedule (one event every `1/rate` seconds) —
/// the open-loop arrival model shared by the in-process CO-corrected
/// injector and the net bench's open-loop driver
/// (`railgun bench-client --rate`): both measure latency against the
/// *intended* arrival instant `i / rate`, never against the possibly
/// delayed actual send, which is exactly the coordinated-omission
/// correction.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalSchedule {
    interarrival_ns: u64,
}

impl ArrivalSchedule {
    /// Schedule at `rate_eps` events/second.
    pub fn new(rate_eps: f64) -> ArrivalSchedule {
        assert!(rate_eps > 0.0);
        ArrivalSchedule {
            interarrival_ns: (1e9 / rate_eps) as u64,
        }
    }

    /// Nanoseconds between intended arrivals.
    pub fn interarrival_ns(&self) -> u64 {
        self.interarrival_ns
    }

    /// Intended arrival of the `i`-th event, in ns since schedule start.
    pub fn intended_ns(&self, i: u64) -> u64 {
        i.saturating_mul(self.interarrival_ns)
    }

    /// Offered load in events/second.
    pub fn offered_eps(&self) -> f64 {
        1e9 / self.interarrival_ns as f64
    }
}

/// Coordinated-omission-corrected latency recorder.
pub struct CoInjector {
    /// The intended arrival cadence.
    schedule: ArrivalSchedule,
    /// Intended start of the next event (ns since measurement start).
    next_intended_ns: u64,
    /// Completion time of the previous event.
    prev_done_ns: u64,
    /// Corrected end-to-end latency histogram.
    pub hist: Histogram,
    /// Raw service-time histogram (no queueing model).
    pub service_hist: Histogram,
    events: u64,
    service_total_ns: u64,
}

/// Summary of an injection run.
#[derive(Debug, Clone)]
pub struct InjectorReport {
    /// Events processed.
    pub events: u64,
    /// Offered load (ev/s).
    pub offered_eps: f64,
    /// Achieved service throughput (ev/s) — capacity of the engine.
    pub capacity_eps: f64,
    /// True if the engine kept up with the offered rate (final backlog
    /// below one interarrival).
    pub kept_up: bool,
}

impl CoInjector {
    /// Injector at `rate_eps` events/second.
    pub fn new(rate_eps: f64) -> CoInjector {
        CoInjector {
            schedule: ArrivalSchedule::new(rate_eps),
            next_intended_ns: 0,
            prev_done_ns: 0,
            hist: Histogram::new(),
            service_hist: Histogram::new(),
            events: 0,
            service_total_ns: 0,
        }
    }

    /// Run `f` as the service of one event and record corrected latency.
    pub fn observe<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let out = f();
        let service_ns = t0.elapsed().as_nanos() as u64;
        self.record_service(service_ns);
        out
    }

    /// Record a pre-measured service time.
    pub fn record_service(&mut self, service_ns: u64) {
        let intended = self.next_intended_ns;
        self.next_intended_ns += self.schedule.interarrival_ns();
        let start = intended.max(self.prev_done_ns);
        let done = start + service_ns;
        self.prev_done_ns = done;
        self.hist.record(done - intended);
        self.service_hist.record(service_ns);
        self.events += 1;
        self.service_total_ns += service_ns;
    }

    /// Current backlog (how far completion trails the arrival clock), ns.
    pub fn backlog_ns(&self) -> u64 {
        self.prev_done_ns.saturating_sub(
            self.next_intended_ns
                .saturating_sub(self.schedule.interarrival_ns()),
        )
    }

    /// Finish and summarize.
    pub fn report(&self) -> InjectorReport {
        let offered_eps = self.schedule.offered_eps();
        let capacity_eps = if self.service_total_ns == 0 {
            f64::INFINITY
        } else {
            self.events as f64 * 1e9 / self.service_total_ns as f64
        };
        InjectorReport {
            events: self.events,
            offered_eps,
            capacity_eps,
            kept_up: self.backlog_ns() <= self.interarrival_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_engine_reports_service_latency() {
        let mut inj = CoInjector::new(1000.0); // 1ms interarrival
        for _ in 0..1000 {
            inj.record_service(100_000); // 0.1ms service
        }
        let r = inj.report();
        assert!(r.kept_up);
        // corrected latency equals service latency when no queueing
        let p99 = inj.hist.quantile(0.99);
        assert!((90_000..=120_000).contains(&p99), "p99={p99}");
        assert!(r.capacity_eps > 5000.0);
    }

    #[test]
    fn slow_engine_accumulates_queueing_delay() {
        let mut inj = CoInjector::new(1000.0); // 1ms interarrival
        for _ in 0..1000 {
            inj.record_service(2_000_000); // 2ms service: 2x overloaded
        }
        let r = inj.report();
        assert!(!r.kept_up);
        // the last event waited ~1000 × 1ms of backlog
        let max = inj.hist.max();
        assert!(
            max > 900_000_000,
            "tail must show ~1s of accumulated queueing, got {max}"
        );
        // while raw service time stays flat at 2ms
        assert!(inj.service_hist.quantile(0.99) < 3_000_000);
    }

    #[test]
    fn bursty_service_recovers() {
        let mut inj = CoInjector::new(1000.0);
        // one 50ms stall then fast events
        inj.record_service(50_000_000);
        for _ in 0..200 {
            inj.record_service(10_000); // 0.01ms
        }
        // CO correction: events right after the stall carry its delay
        let p90 = inj.hist.quantile(0.90);
        assert!(p90 > 1_000_000, "stall visible in corrected p90: {p90}");
        let r = inj.report();
        assert!(r.kept_up, "backlog drains after the stall");
    }

    #[test]
    fn arrival_schedule_cadence() {
        let s = ArrivalSchedule::new(1000.0); // 1ms interarrival
        assert_eq!(s.interarrival_ns(), 1_000_000);
        assert_eq!(s.intended_ns(0), 0);
        assert_eq!(s.intended_ns(7), 7_000_000);
        assert!((s.offered_eps() - 1000.0).abs() < 1e-6);
        // the intended clock saturates instead of overflowing
        let slow = ArrivalSchedule::new(1.0);
        assert_eq!(slow.intended_ns(u64::MAX), u64::MAX);
    }

    #[test]
    fn observe_measures_closure() {
        let mut inj = CoInjector::new(10.0);
        let v = inj.observe(|| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(inj.service_hist.max() >= 2_000_000);
        assert_eq!(inj.report().events, 1);
    }
}
