//! Filter expression mini-language (the plan DAG's Filter operator).
//!
//! Railgun restricts query expressibility to a strict operator order
//! (paper §3.3.2) in exchange for aggressive plan sharing; filters are
//! simple predicate trees over event fields, compiled against the stream
//! schema once at registration time so evaluation is index-based.

use crate::error::{Error, Result};
use crate::event::{EventRead, Schema, Value, ValueRef};

/// Comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    /// `=`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
}

/// An (un-compiled) filter predicate over named fields.
#[derive(Debug, Clone, PartialEq)]
pub enum FilterExpr {
    /// Compare a field against a literal.
    Cmp {
        /// Field name.
        field: String,
        /// Operator.
        op: CmpOp,
        /// Literal to compare against.
        value: Value,
    },
    /// Conjunction.
    And(Box<FilterExpr>, Box<FilterExpr>),
    /// Disjunction.
    Or(Box<FilterExpr>, Box<FilterExpr>),
    /// Negation.
    Not(Box<FilterExpr>),
}

impl FilterExpr {
    /// Convenience: `field op value`.
    pub fn cmp(field: &str, op: CmpOp, value: Value) -> FilterExpr {
        FilterExpr::Cmp {
            field: field.to_string(),
            op,
            value,
        }
    }

    /// `self AND other`.
    pub fn and(self, other: FilterExpr) -> FilterExpr {
        FilterExpr::And(Box::new(self), Box::new(other))
    }

    /// `self OR other`.
    pub fn or(self, other: FilterExpr) -> FilterExpr {
        FilterExpr::Or(Box::new(self), Box::new(other))
    }

    /// Compile against a schema (resolves field names to indices).
    pub fn compile(&self, schema: &Schema) -> Result<CompiledExpr> {
        Ok(match self {
            FilterExpr::Cmp { field, op, value } => {
                let idx = schema
                    .index_of(field)
                    .ok_or_else(|| Error::invalid(format!("filter: unknown field '{field}'")))?;
                CompiledExpr::Cmp {
                    idx,
                    op: *op,
                    value: value.clone(),
                }
            }
            FilterExpr::And(a, b) => {
                CompiledExpr::And(Box::new(a.compile(schema)?), Box::new(b.compile(schema)?))
            }
            FilterExpr::Or(a, b) => {
                CompiledExpr::Or(Box::new(a.compile(schema)?), Box::new(b.compile(schema)?))
            }
            FilterExpr::Not(a) => CompiledExpr::Not(Box::new(a.compile(schema)?)),
        })
    }
}

/// Index-resolved predicate, ready for hot-path evaluation.
#[derive(Debug, Clone, PartialEq)]
pub enum CompiledExpr {
    /// Field-index comparison.
    Cmp {
        /// Field position in the schema.
        idx: usize,
        /// Operator.
        op: CmpOp,
        /// Literal.
        value: Value,
    },
    /// Conjunction.
    And(Box<CompiledExpr>, Box<CompiledExpr>),
    /// Disjunction.
    Or(Box<CompiledExpr>, Box<CompiledExpr>),
    /// Negation.
    Not(Box<CompiledExpr>),
}

impl CompiledExpr {
    /// Evaluate against an event (owned or borrowed view — predicates
    /// read fields as [`ValueRef`]s, so the hot path evaluates straight
    /// off the encoded bytes). Null fields compare false (SQL-ish
    /// three-valued logic collapsed to false).
    pub fn eval<E: EventRead + ?Sized>(&self, event: &E) -> bool {
        match self {
            CompiledExpr::Cmp { idx, op, value } => {
                cmp_values(event.value_ref(*idx), value.as_value_ref(), *op)
            }
            CompiledExpr::And(a, b) => a.eval(event) && b.eval(event),
            CompiledExpr::Or(a, b) => a.eval(event) || b.eval(event),
            CompiledExpr::Not(a) => !a.eval(event),
        }
    }
}

fn cmp_values(lhs: ValueRef<'_>, rhs: ValueRef<'_>, op: CmpOp) -> bool {
    use std::cmp::Ordering;
    let ord: Option<Ordering> = match (lhs, rhs) {
        (ValueRef::Null, _) | (_, ValueRef::Null) => None,
        (ValueRef::Str(a), ValueRef::Str(b)) => Some(a.cmp(b)),
        (ValueRef::Bool(a), ValueRef::Bool(b)) => Some(a.cmp(b)),
        // numerics compare cross-type (I64 vs F64)
        (a, b) => match (a.as_f64(), b.as_f64()) {
            (Some(x), Some(y)) => x.partial_cmp(&y),
            _ => None,
        },
    };
    match ord {
        None => false,
        Some(o) => match op {
            CmpOp::Eq => o == Ordering::Equal,
            CmpOp::Ne => o != Ordering::Equal,
            CmpOp::Lt => o == Ordering::Less,
            CmpOp::Le => o != Ordering::Greater,
            CmpOp::Gt => o == Ordering::Greater,
            CmpOp::Ge => o != Ordering::Less,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{Event, FieldType, Schema, SchemaRef};

    fn schema() -> SchemaRef {
        Schema::of(&[
            ("card", FieldType::Str),
            ("amount", FieldType::F64),
            ("cnp", FieldType::Bool),
            ("n", FieldType::I64),
        ])
        .unwrap()
    }

    fn ev(card: &str, amount: f64, cnp: bool, n: i64) -> Event {
        Event::new(
            0,
            vec![
                Value::Str(card.into()),
                Value::F64(amount),
                Value::Bool(cnp),
                Value::I64(n),
            ],
        )
    }

    #[test]
    fn numeric_comparisons() {
        let s = schema();
        let e = ev("c1", 100.0, true, 5);
        let gt = FilterExpr::cmp("amount", CmpOp::Gt, Value::F64(50.0))
            .compile(&s)
            .unwrap();
        assert!(gt.eval(&e));
        let lt = FilterExpr::cmp("amount", CmpOp::Lt, Value::F64(50.0))
            .compile(&s)
            .unwrap();
        assert!(!lt.eval(&e));
        // i64 field vs f64 literal (cross-type numeric)
        let ge = FilterExpr::cmp("n", CmpOp::Ge, Value::F64(5.0))
            .compile(&s)
            .unwrap();
        assert!(ge.eval(&e));
        let eq = FilterExpr::cmp("n", CmpOp::Eq, Value::I64(5))
            .compile(&s)
            .unwrap();
        assert!(eq.eval(&e));
    }

    #[test]
    fn string_and_bool_comparisons() {
        let s = schema();
        let e = ev("c1", 1.0, true, 0);
        assert!(FilterExpr::cmp("card", CmpOp::Eq, Value::Str("c1".into()))
            .compile(&s)
            .unwrap()
            .eval(&e));
        assert!(FilterExpr::cmp("card", CmpOp::Ne, Value::Str("c2".into()))
            .compile(&s)
            .unwrap()
            .eval(&e));
        assert!(FilterExpr::cmp("cnp", CmpOp::Eq, Value::Bool(true))
            .compile(&s)
            .unwrap()
            .eval(&e));
    }

    #[test]
    fn boolean_combinators() {
        let s = schema();
        let e = ev("c1", 100.0, false, 0);
        let expr = FilterExpr::cmp("amount", CmpOp::Gt, Value::F64(50.0))
            .and(FilterExpr::cmp("cnp", CmpOp::Eq, Value::Bool(true)));
        assert!(!expr.compile(&s).unwrap().eval(&e));
        let expr = FilterExpr::cmp("amount", CmpOp::Gt, Value::F64(50.0))
            .or(FilterExpr::cmp("cnp", CmpOp::Eq, Value::Bool(true)));
        assert!(expr.compile(&s).unwrap().eval(&e));
        let expr = FilterExpr::Not(Box::new(FilterExpr::cmp(
            "cnp",
            CmpOp::Eq,
            Value::Bool(true),
        )));
        assert!(expr.compile(&s).unwrap().eval(&e));
    }

    #[test]
    fn nulls_compare_false() {
        let s = schema();
        let e = Event::new(0, vec![Value::Null, Value::Null, Value::Null, Value::Null]);
        for op in [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Ge] {
            let expr = FilterExpr::cmp("amount", op, Value::F64(1.0))
                .compile(&s)
                .unwrap();
            assert!(!expr.eval(&e), "{op:?} against null must be false");
        }
    }

    #[test]
    fn type_mismatch_compares_false() {
        let s = schema();
        let e = ev("c1", 1.0, true, 0);
        let expr = FilterExpr::cmp("card", CmpOp::Eq, Value::F64(1.0))
            .compile(&s)
            .unwrap();
        assert!(!expr.eval(&e));
    }

    #[test]
    fn unknown_field_fails_compile() {
        let s = schema();
        assert!(FilterExpr::cmp("nope", CmpOp::Eq, Value::I64(1))
            .compile(&s)
            .is_err());
    }
}
