//! The plan DAG (paper §3.3.2): `Window → Filter → GroupBy → Aggregator`
//! with **prefix sharing**.
//!
//! Metrics over the same (topic, partition) compile into one DAG; metrics
//! sharing a window spec share the `Window` node (and therefore its
//! reservoir iterators), metrics sharing a filter share the `Filter`
//! node, and metrics grouping by the same fields share the group-key
//! computation — the optimization Figure 4 of the paper illustrates for
//! Q1/Q2.
//!
//! The Window node is driven by *iterator bundles*: one reservoir
//! iterator per distinct time offset. A window with size `w` and delay
//! `d` subscribes its **arrive** role to the bundle at offset `d` (its
//! tail) and its **expire** role to the bundle at offset `d + w` (its
//! head). Aligned windows therefore share iterators — e.g. all
//! zero-delay sliding windows share one tail iterator at offset 0,
//! reproducing the paper's Figure 3 sharing rule; misaligned windows
//! (Figure 6 bottom) cannot share.
//!
//! The batch-first data plane drives the DAG through
//! [`Plan::advance_batch`]: one call evaluates a whole batch of event
//! timestamps, still **once per event** (accuracy is non-negotiable),
//! while iterator positions carry over between evaluations and
//! state-store write-throughs are coalesced across the batch.
//!
//! ## Gather → kernel evaluation
//!
//! Dispatch does not mutate aggregation states inline. It **gathers**
//! each batch's `(seq, value, raw_hash)` rows into columnar run buffers
//! — one run per (metric, state slot) touched, so consecutive events
//! for the same group land contiguously — and a flush pass applies each
//! run through [`crate::agg::kernel`]'s tight slice loops, then walks
//! an ordered emit log to stream replies exactly as inline evaluation
//! would have. The enum dispatch, slot resolution and per-row aggregate
//! value computation are paid once per run instead of once per row.
//! Run buffers and the emit log are **reused across batches** (recycled
//! through a pool — cleared, never deallocated), so gathering allocates
//! nothing in steady state; a slot holding a gathered run is pinned in
//! the state store until the flush applies it. Kernels accumulate in
//! row order (no float reassociation), keeping replies and persisted
//! states byte-identical to per-event evaluation — see
//! `rust/tests/batch_equivalence.rs`.
//!
//! ## Zero allocations per event (steady state)
//!
//! The per-event evaluation path allocates nothing once every live group
//! has been seen. Evaluation is generic over [`crate::event::EventRead`]:
//! the data plane dispatches borrowed `EventView`s straight off the
//! reservoir's raw chunk bytes (ingestion itself is allocation-free too —
//! see `rust/src/event/view.rs` and the reservoir's raw-append path),
//! while tests and oracles dispatch owned `Event`s through the same code.
//!
//! * group keys are built in a reusable scratch buffer and resolved to a
//!   dense [`GroupId`] by the plan's [`GroupInterner`] — one hash probe;
//!   canonical key bytes and the rendered display string are owned by the
//!   interner and materialized once per group, never per event;
//! * aggregation states live in the [`StateStore`]'s dense slab, indexed
//!   by `(metric_id, GroupId)` — two `Vec` indexings, no key composition,
//!   no byte-key hashing (kvstore keys are composed only when a slot is
//!   created or spilled; the on-disk format is unchanged);
//! * `COUNT_DISTINCT` hashes the aggregated value's key bytes through the
//!   tail of the same scratch buffer instead of a per-event `Vec`;
//! * replies are POD [`MetricReply`]s streamed into a caller-supplied
//!   [`ReplySink`] — the task processor's sink encodes them straight into
//!   its per-shard reply-record buffers, resolving metric/group names
//!   from the interner at encode time ([`ReplyCtx`]), so no per-event
//!   `Vec<MetricReply>` or owned `String`s exist anywhere on the path.
//!
//! Interner ids are rebuilt deterministically by recovery replay (states
//! are reconstructed from the reservoir), so no id mapping is persisted.

pub mod expr;
mod interner;
mod statestore;

pub use expr::{CmpOp, CompiledExpr, FilterExpr};
pub use interner::{GroupId, GroupInterner};
pub use statestore::StateStore;

use crate::agg::{kernel, AggKind, AggState, DEFAULT_BANDS};
use crate::error::{Error, Result};
use crate::event::{EventRead, SchemaRef, Value};
use crate::reservoir::{ResIterator, Reservoir};
use crate::util::clock::TimestampMs;
use crate::util::varint;
use crate::window::WindowSpec;
use std::fmt::Write as _;

/// A metric registration (one aggregation query).
#[derive(Debug, Clone)]
pub struct MetricSpec {
    /// Unique metric name.
    pub name: String,
    /// Aggregation function.
    pub agg: AggKind,
    /// Aggregated field (None only for `COUNT(*)`).
    pub field: Option<String>,
    /// Window specification.
    pub window: WindowSpec,
    /// Group-by fields (may be empty for a global aggregate).
    pub group_by: Vec<String>,
    /// Optional pre-aggregation filter.
    pub filter: Option<FilterExpr>,
    /// ANOMALY_SCORE severity bands in σ units (`None` = 3σ/4σ/5σ);
    /// ignored by every other aggregation.
    pub bands: Option<[f64; 3]>,
}

impl MetricSpec {
    /// Convenience constructor for the common `agg(field) group by g` case.
    pub fn new(
        name: &str,
        agg: AggKind,
        field: Option<&str>,
        window: WindowSpec,
        group_by: &[&str],
    ) -> MetricSpec {
        MetricSpec {
            name: name.to_string(),
            agg,
            field: field.map(|s| s.to_string()),
            window,
            group_by: group_by.iter().map(|s| s.to_string()).collect(),
            filter: None,
            bands: None,
        }
    }

    /// Attach a filter.
    pub fn with_filter(mut self, f: FilterExpr) -> MetricSpec {
        self.filter = Some(f);
        self
    }

    /// Configure ANOMALY_SCORE severity bands (σ thresholds, ascending).
    pub fn with_bands(mut self, bands: [f64; 3]) -> MetricSpec {
        self.bands = Some(bands);
        self
    }
}

/// One per-event metric result — plain old data; metric and group names
/// are resolved from a [`ReplyCtx`] at encode/render time, never cloned
/// on the hot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricReply {
    /// Metric id within this plan.
    pub metric_id: u32,
    /// Interned group key.
    pub group_id: GroupId,
    /// Aggregate value after this event (None = empty-window identity).
    pub value: Option<f64>,
    /// Timestamp of the triggering event.
    pub event_ts: TimestampMs,
}

/// Name/display resolution handed to [`ReplySink`] callbacks: borrows the
/// plan's metric table and group interner for the duration of one
/// callback.
pub struct ReplyCtx<'a> {
    topo: &'a Topo,
    interner: &'a GroupInterner,
}

impl ReplyCtx<'_> {
    /// Metric name by id.
    #[inline]
    pub fn metric_name(&self, metric_id: u32) -> &str {
        &self.topo.metric_names[metric_id as usize]
    }

    /// Rendered group key (group-by field values joined with `,`).
    #[inline]
    pub fn group(&self, group_id: GroupId) -> &str {
        self.interner.display(group_id)
    }
}

/// Receives the replies of an evaluation as they are produced — the
/// zero-allocation alternative to returning `Vec`s of owned replies.
///
/// [`Plan::advance_batch`] pushes every reply of the evaluation at
/// `t_evals[i]` via [`ReplySink::push`], then calls
/// [`ReplySink::event_done`] exactly once per **successful** evaluation
/// (aligned with `t_evals` order). Replies pushed by an evaluation that
/// then fails receive no `event_done` — sinks that buffer per event
/// should discard the partial event on the next boundary or batch.
pub trait ReplySink {
    /// One metric reply of the current evaluation.
    fn push(&mut self, ctx: &ReplyCtx<'_>, reply: MetricReply);
    /// The evaluation at `t_eval` completed (even when it produced no
    /// replies — the task processor publishes an empty reply message so
    /// clients still get their per-event acknowledgement).
    fn event_done(&mut self, _ctx: &ReplyCtx<'_>, _t_eval: TimestampMs) {}
}

/// Discarding sink (recovery replay, backfill).
impl ReplySink for () {
    #[inline]
    fn push(&mut self, _ctx: &ReplyCtx<'_>, _reply: MetricReply) {}
}

/// An owned, display-resolved reply — test/demo/oracle convenience; the
/// hot path streams POD [`MetricReply`]s instead.
#[derive(Debug, Clone, PartialEq)]
pub struct ResolvedReply {
    /// Metric id within the plan.
    pub metric_id: u32,
    /// Metric name.
    pub metric: String,
    /// Rendered group key (fields joined with `,`).
    pub group: String,
    /// Aggregate value after this event.
    pub value: Option<f64>,
    /// Timestamp of the triggering event.
    pub event_ts: TimestampMs,
}

/// Sink that materializes owned [`ResolvedReply`]s grouped per
/// evaluation (tests, demos — allocates freely by design).
#[derive(Default)]
pub struct CollectingSink {
    /// Replies per completed evaluation, aligned with the `t_evals` of
    /// the driving `advance_batch` call.
    pub events: Vec<Vec<ResolvedReply>>,
    current: Vec<ResolvedReply>,
}

impl ReplySink for CollectingSink {
    fn push(&mut self, ctx: &ReplyCtx<'_>, r: MetricReply) {
        self.current.push(ResolvedReply {
            metric_id: r.metric_id,
            metric: ctx.metric_name(r.metric_id).to_string(),
            group: ctx.group(r.group_id).to_string(),
            value: r.value,
            event_ts: r.event_ts,
        });
    }

    fn event_done(&mut self, _ctx: &ReplyCtx<'_>, _t_eval: TimestampMs) {
        self.events.push(std::mem::take(&mut self.current));
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    Arrive,
    Expire,
}

struct AggNode {
    metric_id: u32,
    kind: AggKind,
    field_idx: Option<usize>,
    /// Owning group node — salts the intern key, and lets the query path
    /// rebuild the salted key for lookups.
    group_idx: usize,
    /// ANOMALY_SCORE severity bands baked into fresh states.
    bands: [f64; 3],
}

struct GroupNode {
    field_idxs: Vec<usize>,
    aggs: Vec<usize>,
}

struct FilterNode {
    expr: Option<CompiledExpr>,
    groups: Vec<usize>,
}

struct WindowNode {
    spec: WindowSpec,
    filters: Vec<usize>,
}

struct Bundle {
    offset_ms: i64,
    iter: ResIterator,
    /// (window node, role) pairs fed by this iterator.
    subs: Vec<(usize, Role)>,
}

struct Topo {
    schema: SchemaRef,
    windows: Vec<WindowNode>,
    filters: Vec<FilterNode>,
    groups: Vec<GroupNode>,
    aggs: Vec<AggNode>,
    metric_names: Vec<String>,
}

/// `run_of` sentinel: this slot has no gathered run. In the emit log it
/// additionally marks a reply whose (metric, group) has no state
/// anywhere — the value is `None` without touching a run.
const NO_RUN: u32 = u32::MAX;

/// A maximal stretch of equally-shaped rows within a run: all additions
/// or all evictions, all emitting replies or none.
struct RunSeg {
    add: bool,
    emit: bool,
    len: u32,
}

/// Pending columnar updates for one (metric, state slot): parallel
/// `(seq, value, raw_hash, include)` columns in dispatch order, split
/// into [`RunSeg`]s and flushed through the batch kernels.
#[derive(Default)]
struct Run {
    slot: u32,
    segs: Vec<RunSeg>,
    seqs: Vec<u64>,
    vals: Vec<f64>,
    hashes: Vec<u64>,
    /// Row participates in the aggregate (SQL null semantics); excluded
    /// rows exist only to read the current value for their reply.
    incl: Vec<bool>,
    /// Post-row aggregate values of emitting rows, filled by the flush.
    out: Vec<Option<f64>>,
    /// Emitting rows gathered so far (= the next row's `out` index).
    n_emit: u32,
    /// At least one row mutates the state (persistence is skipped for
    /// read-only runs, like the scalar path's `value()` reads).
    mutated: bool,
}

impl Run {
    /// Re-arm a pooled (or fresh) buffer for `slot`: row columns empty,
    /// capacity retained.
    fn reset(&mut self, slot: u32) {
        self.slot = slot;
        self.segs.clear();
        self.seqs.clear();
        self.vals.clear();
        self.hashes.clear();
        self.incl.clear();
        self.out.clear();
        self.n_emit = 0;
        self.mutated = false;
    }

    fn push_row(&mut self, add: bool, emit: bool, seq: u64, val: f64, hash: u64, include: bool) {
        match self.segs.last_mut() {
            Some(s) if s.add == add && s.emit == emit => s.len += 1,
            _ => self.segs.push(RunSeg { add, emit, len: 1 }),
        }
        self.seqs.push(seq);
        self.vals.push(val);
        self.hashes.push(hash);
        self.incl.push(include);
    }
}

/// One sink callback recorded during gather, replayed in order by the
/// flush — the reply stream is byte-identical to inline evaluation.
enum EmitLogEntry {
    /// `sink.push` of one metric reply; the value is
    /// `runs[run].out[out_idx]`, or `None` when `run == NO_RUN`.
    Reply {
        run: u32,
        out_idx: u32,
        metric_id: u32,
        group: GroupId,
        event_ts: TimestampMs,
    },
    /// `sink.event_done` of a successfully gathered evaluation.
    EventDone(TimestampMs),
}

/// Reusable gather buffers: live runs in creation order, a recycling
/// pool, the slot→run index and the ordered emit log. All four are
/// drained by the flush and reused by the next batch — no per-batch
/// allocation in steady state.
#[derive(Default)]
struct GatherBufs {
    runs: Vec<Run>,
    pool: Vec<Run>,
    /// Slot id → index into `runs` (`NO_RUN` when none), lazily sized.
    run_of: Vec<u32>,
    emit_log: Vec<EmitLogEntry>,
}

/// A compiled plan over one task processor's reservoir + state store.
pub struct Plan {
    topo: Topo,
    bundles: Vec<Bundle>,
    state: StateStore,
    interner: GroupInterner,
    gather: GatherBufs,
    last_t_eval: TimestampMs,
    key_scratch: Vec<u8>,
}

impl Plan {
    /// Compile `specs` into a shared DAG. Iterators start at sequence 0 —
    /// callers recovering from a checkpoint must call
    /// [`Plan::restore_positions`] before the first advance.
    pub fn build(
        schema: SchemaRef,
        specs: &[MetricSpec],
        reservoir: &Reservoir,
        state: StateStore,
    ) -> Result<Plan> {
        let mut plan = Plan {
            topo: Topo {
                schema,
                windows: Vec::new(),
                filters: Vec::new(),
                groups: Vec::new(),
                aggs: Vec::new(),
                metric_names: Vec::new(),
            },
            bundles: Vec::new(),
            state,
            interner: GroupInterner::new(),
            gather: GatherBufs::default(),
            last_t_eval: i64::MIN,
            key_scratch: Vec::with_capacity(64),
        };
        for spec in specs {
            plan.register(spec, reservoir)?;
        }
        Ok(plan)
    }

    /// Register a metric into the DAG (with prefix sharing); returns its
    /// metric id. Does **not** backfill — see [`Plan::add_metric_backfill`].
    pub fn register(&mut self, spec: &MetricSpec, reservoir: &Reservoir) -> Result<u32> {
        spec.window.validate()?;
        if spec.name.is_empty() {
            return Err(Error::invalid("metric name must not be empty"));
        }
        if self.topo.metric_names.iter().any(|n| n == &spec.name) {
            return Err(Error::invalid(format!("metric '{}' already exists", spec.name)));
        }
        if spec.agg.needs_field() && spec.field.is_none() {
            return Err(Error::invalid(format!(
                "metric '{}': {:?} needs a field",
                spec.name, spec.agg
            )));
        }
        let field_idx = match &spec.field {
            Some(f) => Some(
                self.topo
                    .schema
                    .index_of(f)
                    .ok_or_else(|| Error::invalid(format!("unknown field '{f}'")))?,
            ),
            None => None,
        };
        let group_idxs: Vec<usize> = spec
            .group_by
            .iter()
            .map(|g| {
                self.topo
                    .schema
                    .index_of(g)
                    .ok_or_else(|| Error::invalid(format!("unknown group-by field '{g}'")))
            })
            .collect::<Result<_>>()?;
        let compiled = match &spec.filter {
            Some(f) => Some(f.compile(&self.topo.schema)?),
            None => None,
        };

        // window node (shared by spec equality)
        let w_idx = match self.topo.windows.iter().position(|w| w.spec == spec.window) {
            Some(i) => i,
            None => {
                self.topo.windows.push(WindowNode {
                    spec: spec.window,
                    filters: Vec::new(),
                });
                let w_idx = self.topo.windows.len() - 1;
                // subscribe its bundles
                self.subscribe(spec.window.tail_offset(), w_idx, Role::Arrive, reservoir);
                self.subscribe(spec.window.head_offset(), w_idx, Role::Expire, reservoir);
                w_idx
            }
        };
        // filter node (shared within the window)
        let f_idx = match self.topo.windows[w_idx]
            .filters
            .iter()
            .find(|&&f| self.topo.filters[f].expr == compiled)
        {
            Some(&i) => i,
            None => {
                self.topo.filters.push(FilterNode {
                    expr: compiled,
                    groups: Vec::new(),
                });
                let f_idx = self.topo.filters.len() - 1;
                self.topo.windows[w_idx].filters.push(f_idx);
                f_idx
            }
        };
        // group node (shared within the filter)
        let g_idx = match self.topo.filters[f_idx]
            .groups
            .iter()
            .find(|&&g| self.topo.groups[g].field_idxs == group_idxs)
        {
            Some(&i) => i,
            None => {
                self.topo.groups.push(GroupNode {
                    field_idxs: group_idxs,
                    aggs: Vec::new(),
                });
                let g_idx = self.topo.groups.len() - 1;
                self.topo.filters[f_idx].groups.push(g_idx);
                g_idx
            }
        };
        // aggregator leaf
        let metric_id = self.topo.metric_names.len() as u32;
        self.topo.metric_names.push(spec.name.clone());
        self.topo.aggs.push(AggNode {
            metric_id,
            kind: spec.agg,
            field_idx,
            group_idx: g_idx,
            bands: spec.bands.unwrap_or(DEFAULT_BANDS),
        });
        let a_idx = self.topo.aggs.len() - 1;
        // one agg node per metric, pushed in registration order — the
        // query path relies on aggs[metric_id] being this metric's node
        debug_assert_eq!(a_idx as u32, metric_id);
        self.topo.groups[g_idx].aggs.push(a_idx);
        Ok(metric_id)
    }

    fn subscribe(&mut self, offset_ms: i64, w_idx: usize, role: Role, reservoir: &Reservoir) {
        match self.bundles.iter_mut().find(|b| b.offset_ms == offset_ms) {
            Some(b) => b.subs.push((w_idx, role)),
            None => {
                // keep bundles ordered by decreasing offset at registration
                // time: expirations (large offsets) must drain before the
                // live arrival frontier (offset 0), and hoisting the order
                // here saves a sort on every advance() call
                let pos = self.bundles.partition_point(|b| b.offset_ms > offset_ms);
                self.bundles.insert(
                    pos,
                    Bundle {
                        offset_ms,
                        iter: reservoir.iterator_at(0),
                        subs: vec![(w_idx, role)],
                    },
                );
            }
        }
    }

    /// Advance evaluation time to `t_eval` (must be monotonic), draining
    /// every iterator bundle up to its bound and updating aggregation
    /// states. Replies of arrivals at offset 0 (the live arrival
    /// frontier) stream into `sink`; `sink.event_done` fires once on
    /// success. This is the hot path — it performs no allocations in
    /// steady state.
    pub fn advance_into<S: ReplySink + ?Sized>(
        &mut self,
        t_eval: TimestampMs,
        sink: &mut S,
    ) -> Result<()> {
        let gathered = self.gather_eval(t_eval);
        // flush even when the gather failed: the replies of the gathered
        // prefix must still reach the sink, and pinned slots release
        let flushed = self.flush_runs(sink);
        gathered.and(flushed)
    }

    /// Gather one evaluation's rows into the columnar run buffers
    /// without applying them. On success the emit log gains the
    /// evaluation's replies and its `event_done`; on failure the rows
    /// gathered so far stay pending — the caller must still
    /// [`flush_runs`](Plan::flush_runs) to release pinned slots and
    /// deliver the successfully gathered prefix.
    fn gather_eval(&mut self, t_eval: TimestampMs) -> Result<()> {
        if t_eval < self.last_t_eval {
            return Err(Error::invalid(format!(
                "advance: t_eval went backwards ({t_eval} < {})",
                self.last_t_eval
            )));
        }
        // Bundles are kept in decreasing offset order by subscribe():
        // expirations (large offsets) update state before the live arrival
        // (offset 0) emits its replies, so every reply reflects the exact
        // window content at T_eval. The ordering invariant is maintained
        // at registration time — no per-advance sort.
        let mut bundles = std::mem::take(&mut self.bundles);
        debug_assert!(bundles.windows(2).all(|w| w[0].offset_ms >= w[1].offset_ms));
        let mut failed: Option<Error> = None;
        'outer: for b in &mut bundles {
            let bound = t_eval - b.offset_ms;
            let emit = b.offset_ms == 0;
            loop {
                match b.iter.peek_ts() {
                    Ok(Some(ts)) if ts < bound => {}
                    Ok(_) => break,
                    Err(e) => {
                        failed = Some(e);
                        break 'outer;
                    }
                }
                let topo = &self.topo;
                let state = &mut self.state;
                let interner = &mut self.interner;
                let gather = &mut self.gather;
                let scratch = &mut self.key_scratch;
                let subs = &b.subs;
                let mut inner_err: Option<Error> = None;
                let stepped = b.iter.next(|seq, event| {
                    for (w_idx, role) in subs {
                        if let Err(e) = gather_dispatch(
                            topo,
                            state,
                            interner,
                            gather,
                            scratch,
                            *w_idx,
                            *role,
                            seq,
                            event,
                            emit,
                            None,
                        ) {
                            inner_err = Some(e);
                            return;
                        }
                    }
                });
                if let Some(e) = inner_err {
                    failed = Some(e);
                    break 'outer;
                }
                match stepped {
                    Ok(Some(())) => {}
                    Ok(None) => break,
                    Err(e) => {
                        failed = Some(e);
                        break 'outer;
                    }
                }
            }
        }
        self.bundles = bundles;
        if let Some(e) = failed {
            return Err(e);
        }
        self.last_t_eval = t_eval;
        self.gather.emit_log.push(EmitLogEntry::EventDone(t_eval));
        Ok(())
    }

    /// Apply every gathered run through the batch kernels
    /// ([`crate::agg::kernel`]) and replay the emit log into `sink`.
    /// Always drains the gather buffers completely — every pinned slot
    /// releases even when a run fails to persist (the first error is
    /// reported after the walk) — and recycles the run buffers into the
    /// pool for the next batch.
    fn flush_runs<S: ReplySink + ?Sized>(&mut self, sink: &mut S) -> Result<()> {
        let mut first_err: Option<Error> = None;
        let mut runs = std::mem::take(&mut self.gather.runs);
        for run in &mut runs {
            self.gather.run_of[run.slot as usize] = NO_RUN;
            let res = self.state.apply_run(run.slot, run.mutated, |st| {
                let mut start = 0usize;
                for seg in &run.segs {
                    let end = start + seg.len as usize;
                    let seqs = &run.seqs[start..end];
                    let vals = &run.vals[start..end];
                    let hashes = &run.hashes[start..end];
                    if seg.emit {
                        kernel::add_run_emit(
                            st,
                            seqs,
                            vals,
                            hashes,
                            &run.incl[start..end],
                            &mut run.out,
                        );
                    } else if seg.add {
                        kernel::add_run(st, seqs, vals, hashes);
                    } else {
                        kernel::evict_run(st, seqs, vals, hashes);
                    }
                    start = end;
                }
            });
            if let Err(e) = res {
                first_err.get_or_insert(e);
            }
        }
        let ctx = ReplyCtx {
            topo: &self.topo,
            interner: &self.interner,
        };
        for entry in self.gather.emit_log.drain(..) {
            match entry {
                EmitLogEntry::Reply {
                    run,
                    out_idx,
                    metric_id,
                    group,
                    event_ts,
                } => {
                    let value = if run == NO_RUN {
                        None
                    } else {
                        runs[run as usize].out[out_idx as usize]
                    };
                    sink.push(
                        &ctx,
                        MetricReply {
                            metric_id,
                            group_id: group,
                            value,
                            event_ts,
                        },
                    );
                }
                EmitLogEntry::EventDone(t) => sink.event_done(&ctx, t),
            }
        }
        self.gather.pool.append(&mut runs);
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// [`Plan::advance_into`] with collected, display-resolved replies —
    /// the single-event convenience for tests, demos and oracles (it
    /// allocates; the data plane uses sinks).
    pub fn advance(&mut self, t_eval: TimestampMs) -> Result<Vec<ResolvedReply>> {
        let mut sink = CollectingSink::default();
        self.advance_into(t_eval, &mut sink)?;
        Ok(sink.events.pop().unwrap_or_default())
    }

    /// Advance evaluation time through a whole batch of per-event
    /// timestamps, streaming the replies of each evaluation into `sink`
    /// (one `event_done` per `t_evals` entry, in order).
    ///
    /// **Every window is still evaluated at every event timestamp** —
    /// batching changes none of the paper's per-event accuracy semantics.
    /// What it amortizes: the iterator bundles keep their positions
    /// between consecutive evaluations (no re-seek), dispatch gathers the
    /// whole batch's rows into columnar runs applied through the batch
    /// kernels in one flush (so a group touched by many events pays slot
    /// resolution and kernel dispatch once), and state-store
    /// write-throughs are deferred and coalesced so that group is also
    /// persisted once ([`StateStore::begin_deferred`]). Replies still
    /// reach `sink` in exact per-event order.
    ///
    /// On error, the sink has received the replies of the successfully
    /// evaluated prefix (so callers can still publish them), and the
    /// coalesced state writes of that prefix are flushed.
    ///
    /// `t_evals` must be monotonically non-decreasing (callers clamp
    /// event-time jitter, as the single-event path does).
    pub fn advance_batch<S: ReplySink + ?Sized>(
        &mut self,
        t_evals: &[TimestampMs],
        sink: &mut S,
    ) -> Result<()> {
        self.state.begin_deferred();
        let mut failed: Option<Error> = None;
        for &t_eval in t_evals {
            if let Err(e) = self.gather_eval(t_eval) {
                failed = Some(e);
                break;
            }
        }
        // apply + emit the gathered prefix even on failure, then flush
        // the coalesced writes: the kvstore must not lag the cache for
        // states already mutated by this batch
        let applied = self.flush_runs(sink);
        let flushed = self.state.end_deferred();
        if let Some(e) = failed {
            return Err(e);
        }
        applied.and(flushed)
    }

    /// Add a metric at runtime and **backfill** its state from the
    /// reservoir history (the paper's §5 future-work item). Returns the
    /// new metric id.
    pub fn add_metric_backfill(
        &mut self,
        spec: &MetricSpec,
        reservoir: &Reservoir,
    ) -> Result<u32> {
        let metric_id = self.register(spec, reservoir)?;
        if self.last_t_eval == i64::MIN {
            return Ok(metric_id); // nothing processed yet
        }
        // find the window node of the new metric
        let w_idx = self
            .topo
            .windows
            .iter()
            .position(|w| w.spec == spec.window)
            .expect("window registered above");
        // replay history into this metric only, via temp iterators; the
        // rows gather like any batch and flush through the kernels once
        // both passes finish (add rows precede evict rows in each run,
        // matching the pass order)
        let mut gathered: Result<()> = Ok(());
        'passes: for (offset, role) in [
            (spec.window.tail_offset(), Role::Arrive),
            (spec.window.head_offset(), Role::Expire),
        ] {
            let bound = self.last_t_eval - offset;
            let mut it = reservoir.iterator_at(0);
            loop {
                match it.peek_ts() {
                    Ok(Some(ts)) if ts < bound => {}
                    Ok(_) => break,
                    Err(e) => {
                        gathered = Err(e);
                        break 'passes;
                    }
                }
                let topo = &self.topo;
                let state = &mut self.state;
                let interner = &mut self.interner;
                let gather = &mut self.gather;
                let scratch = &mut self.key_scratch;
                let mut inner_err: Option<Error> = None;
                let stepped = it.next(|seq, event| {
                    if let Err(e) = gather_dispatch(
                        topo,
                        state,
                        interner,
                        gather,
                        scratch,
                        w_idx,
                        role,
                        seq,
                        event,
                        false,
                        Some(metric_id),
                    ) {
                        inner_err = Some(e);
                    }
                });
                if let Some(e) = inner_err {
                    gathered = Err(e);
                    break 'passes;
                }
                if let Err(e) = stepped {
                    gathered = Err(e);
                    break 'passes;
                }
            }
            // a freshly-created bundle must start where the backfill ended
            if let Some(b) = self.bundles.iter_mut().find(|b| b.offset_ms == offset) {
                if b.iter.seq() == 0 {
                    b.iter.seek(it.seq());
                }
            }
        }
        // flush even on failure so pinned slots release
        let flushed = self.flush_runs(&mut ());
        gathered.and(flushed)?;
        Ok(metric_id)
    }

    /// Current aggregate value for a metric + group key values.
    pub fn value_for(&mut self, metric: &str, group_values: &[Value]) -> Result<Option<f64>> {
        let metric_id = self
            .topo
            .metric_names
            .iter()
            .position(|n| n == metric)
            .ok_or_else(|| Error::not_found(format!("metric '{metric}'")))?
            as u32;
        // rebuild the salted intern key (group-node index prefix); the
        // salt is stripped again for state-store keys, which stay in the
        // on-disk format
        let g_idx = self.topo.aggs[metric_id as usize].group_idx;
        let mut key = Vec::with_capacity(32);
        varint::write_u32(&mut key, g_idx as u32);
        let salt_len = key.len();
        for v in group_values {
            v.key_bytes(&mut key);
            key.push(0x1f);
        }
        match self.interner.lookup(&key) {
            Some(group) => self.state.value(metric_id, group, &key[salt_len..]),
            // a group this plan instance never dispatched can only exist
            // as a persisted state in a reopened kvstore
            None => self.state.value_by_key(metric_id, &key[salt_len..]),
        }
    }

    /// Metric name by id.
    pub fn metric_name(&self, metric_id: u32) -> Option<&str> {
        self.topo.metric_names.get(metric_id as usize).map(|s| s.as_str())
    }

    /// Number of registered metrics.
    pub fn metric_count(&self) -> usize {
        self.topo.metric_names.len()
    }

    /// Number of groups interned so far (observability).
    pub fn interned_groups(&self) -> usize {
        self.interner.len()
    }

    /// Number of live reservoir iterators (the paper's Figure 6 x-axis).
    pub fn iterator_count(&self) -> usize {
        self.bundles.len()
    }

    /// DAG node counts `(windows, filters, groups, aggs)` — prefix-sharing
    /// observability, used by the ablation bench.
    pub fn node_counts(&self) -> (usize, usize, usize, usize) {
        (
            self.topo.windows.len(),
            self.topo.filters.len(),
            self.topo.groups.len(),
            self.topo.aggs.len(),
        )
    }

    /// Last evaluation time.
    pub fn last_t_eval(&self) -> TimestampMs {
        self.last_t_eval
    }

    /// Iterator positions per bundle offset, sorted by offset
    /// (checkpointing).
    pub fn positions(&self) -> Vec<(i64, u64)> {
        let mut v: Vec<(i64, u64)> = self
            .bundles
            .iter()
            .map(|b| (b.offset_ms, b.iter.seq()))
            .collect();
        v.sort_unstable();
        v
    }

    /// Restore iterator positions + evaluation time from a checkpoint.
    /// Under full replay the group interner needs no restoring — states
    /// are rebuilt by replaying the reservoir, which re-interns every
    /// live group; a snapshot recovery restores it explicitly via
    /// [`Plan::restore_interner`] first.
    pub fn restore_positions(&mut self, positions: &[(i64, u64)], t_eval: TimestampMs) {
        for (offset, seq) in positions {
            if let Some(b) = self.bundles.iter_mut().find(|b| b.offset_ms == *offset) {
                b.iter.seek(*seq);
            }
        }
        self.last_t_eval = t_eval;
    }

    /// Window offsets of every bundle, sorted (snapshot validity: a
    /// snapshot must carry a position for each of these).
    pub fn bundle_offsets(&self) -> Vec<i64> {
        let mut v: Vec<i64> = self.bundles.iter().map(|b| b.offset_ms).collect();
        v.sort_unstable();
        v
    }

    /// The interner's checkpoint image (entries in dense id order).
    pub fn export_interner(&self) -> Vec<(Vec<u8>, String)> {
        self.interner.export()
    }

    /// Restore the interner from a snapshot image, reproducing the
    /// original `GroupId` assignment. Must run before any dispatch.
    pub fn restore_interner(&mut self, entries: &[(Vec<u8>, String)]) -> Result<()> {
        self.interner.restore(entries)
    }

    /// Access the state store (checkpoint flush, stats).
    pub fn state(&mut self) -> &mut StateStore {
        &mut self.state
    }
}

/// Render a group's display string — runs once per interned group, not
/// per event. Byte-for-byte identical to the per-reply rendering the
/// pre-interning path produced (`values joined with ','`).
fn render_group<E: EventRead + ?Sized>(gnode: &GroupNode, event: &E) -> String {
    let mut s = String::new();
    for (i, &idx) in gnode.field_idxs.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{}", event.value_ref(idx));
    }
    s
}

/// Route one event through a window node's sub-DAG, **gathering** its
/// rows into the columnar run buffers instead of mutating states
/// inline; [`Plan::flush_runs`] applies them through the batch kernels.
/// Generic over [`EventRead`]: the data plane dispatches borrowed
/// reservoir views (`EventView`), while tests and oracles dispatch
/// owned `Event`s.
#[allow(clippy::too_many_arguments)]
fn gather_dispatch<E: EventRead + ?Sized>(
    topo: &Topo,
    state: &mut StateStore,
    interner: &mut GroupInterner,
    gather: &mut GatherBufs,
    scratch: &mut Vec<u8>,
    w_idx: usize,
    role: Role,
    seq: u64,
    event: &E,
    emit: bool,
    only_metric: Option<u32>,
) -> Result<()> {
    let win = &topo.windows[w_idx];
    for &f_idx in &win.filters {
        let fnode = &topo.filters[f_idx];
        if let Some(expr) = &fnode.expr {
            if !expr.eval(event) {
                continue;
            }
        }
        for &g_idx in &fnode.groups {
            let gnode = &topo.groups[g_idx];
            // group key: field key-bytes joined by 0x1f separators,
            // hashed once by the interner and resolved to a dense id.
            // The group-node index salts the interned bytes (varint
            // prefix), so colliding byte tuples from differently-typed
            // field sets cannot share a display string; the salt is
            // stripped before the key reaches the state store, keeping
            // the on-disk key format unchanged.
            scratch.clear();
            varint::write_u32(scratch, g_idx as u32);
            let salt_len = scratch.len();
            for &idx in &gnode.field_idxs {
                event.value_ref(idx).key_bytes(scratch);
                scratch.push(0x1f);
            }
            let group = interner.intern(&scratch[..], || render_group(gnode, event));
            let group_key_len = scratch.len();
            for &a_idx in &gnode.aggs {
                let anode = &topo.aggs[a_idx];
                if let Some(only) = only_metric {
                    if anode.metric_id != only {
                        continue;
                    }
                }
                // aggregate input per SQL null semantics; COUNT_DISTINCT
                // hashes through the scratch tail (no per-event Vec)
                let (val, raw_hash, include) = match anode.field_idx {
                    None => (0.0, 0u64, true),
                    Some(fi) => crate::agg::resolve_input(
                        anode.kind,
                        event.value_ref(fi),
                        scratch,
                        group_key_len,
                    ),
                };
                let emitting = emit && role == Role::Arrive;
                if !include && !emitting {
                    // the scalar path only did a read-only value() here;
                    // nothing to gather
                    continue;
                }
                let kind = anode.kind;
                let bands = anode.bands;
                let group_key = &scratch[salt_len..group_key_len];
                let slot = if include {
                    let mut init = || AggState::new_banded(kind, bands);
                    state.gather_slot(anode.metric_id, group, group_key, Some(&mut init))?
                } else {
                    state.gather_slot(anode.metric_id, group, group_key, None)?
                };
                let Some(slot) = slot else {
                    // excluded row over a state that exists nowhere: the
                    // reply value is None, recorded without a run
                    gather.emit_log.push(EmitLogEntry::Reply {
                        run: NO_RUN,
                        out_idx: 0,
                        metric_id: anode.metric_id,
                        group,
                        event_ts: event.timestamp(),
                    });
                    continue;
                };
                // resolve (or start) this slot's run
                let s = slot as usize;
                if gather.run_of.len() <= s {
                    gather.run_of.resize(s + 1, NO_RUN);
                }
                let mut r = gather.run_of[s];
                if r == NO_RUN {
                    r = gather.runs.len() as u32;
                    gather.run_of[s] = r;
                    let mut run = gather.pool.pop().unwrap_or_default();
                    run.reset(slot);
                    gather.runs.push(run);
                }
                let run = &mut gather.runs[r as usize];
                run.push_row(role == Role::Arrive, emitting, seq, val, raw_hash, include);
                if include {
                    run.mutated = true;
                }
                if emitting {
                    gather.emit_log.push(EmitLogEntry::Reply {
                        run: r,
                        out_idx: run.n_emit,
                        metric_id: anode.metric_id,
                        group,
                        event_ts: event.timestamp(),
                    });
                    run.n_emit += 1;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests;
