//! The aggregation **state store** (paper §3.3.2): a dense in-memory
//! slab fronting kvstore persistence.
//!
//! ## Slab layout (zero allocations per event)
//!
//! Live states sit in a dense `Vec<Slot>` slab; the hot path resolves
//! `(metric_id, GroupId)` to a slot through `slot_of[metric_id][group_id]`
//! — two array indexings, no hashing, no key composition. Group ids come
//! from the plan's group-key interner ([`crate::plan::GroupInterner`]),
//! which is the only place group-key bytes are hashed (once per event
//! and group node).
//!
//! The composed kvstore key `varint(metric_id) ++ group_key_bytes` is
//! materialized **once**, when a slot is created, and cached in the slot
//! for every later write-through/spill — the **on-disk format is
//! unchanged** from the byte-keyed store, so persisted states survive
//! this refactor and `value_by_key` can still read them without an id.
//!
//! Capacity is in slots; eviction is a **clock / second-chance sweep**
//! over the dense slot vec: every slot touch sets a referenced bit, and
//! the sweep hand clears bits until it finds an untouched slot to spill
//! — hot groups survive spills (regression-tested), the sweep state is
//! one `usize` hand, and no per-touch queue maintenance happens on the
//! hot path (the previous insertion-order queue ignored touches
//! entirely). A spilled dirty state hits the kvstore first, then the
//! slot recycles through a free list, which bounds the **state** memory
//! (the heavy part — aggregation payloads) even with unbounded group-by
//! cardinality.
//! Evicted states reload from the kvstore on next touch. Two small
//! per-group residues do grow with total distinct groups seen: the
//! `slot_of` index rows (4 bytes per (metric, group)) and the plan's
//! interner entries (key bytes + display string per group, never
//! evicted) — a deliberate trade for the zero-allocation hot path; see
//! the ROADMAP follow-up on interner eviction.
//!
//! ## Deferred mode
//!
//! [`StateStore::begin_deferred`] / [`StateStore::end_deferred`] coalesce
//! write-throughs across a batch: updates push their **slot id** into a
//! dense dirty `Vec<u32>` (deduplicated by a per-slot flag) and the batch
//! end persists each dirty state once. Draining moves no key bytes —
//! the pre-slab store cloned every dirty `Vec<u8>` key per batch; the
//! dirty vec is drained in place and its capacity is reused across
//! batches (see the `end_deferred_*` regression tests). Eviction of a
//! dirty slot persists it first, so the kvstore never lags the cache for
//! states that leave memory.

use crate::agg::{AggKind, AggState};
use crate::error::Result;
use crate::kvstore::Store;
use crate::plan::GroupId;
use crate::util::varint;
use std::sync::Arc;

/// `slot_of` sentinel: no slot for this (metric, group).
const NO_SLOT: u32 = u32::MAX;

/// One cached aggregation state.
struct Slot {
    state: AggState,
    /// Composed kvstore key (`varint(metric_id) ++ group_key_bytes`),
    /// allocated once at slot creation and reused for every persist.
    key: Box<[u8]>,
    metric_id: u32,
    group_id: u32,
    /// Slot id is in the deferred dirty vec.
    dirty: bool,
    /// Occupied; false ⇒ on the free list.
    live: bool,
    /// Second-chance bit: set on every touch, cleared by the clock
    /// sweep; an unreferenced slot is the next eviction victim.
    referenced: bool,
    /// Slot holds a pending gather run ([`StateStore::gather_slot`]):
    /// the clock sweep must not evict it, or the plan's slot→run
    /// linkage would dangle mid-batch. Cleared by
    /// [`StateStore::apply_run`].
    pinned: bool,
}

/// Cached, persistent aggregation states keyed by `(metric_id, GroupId)`.
pub struct StateStore {
    store: Arc<Store>,
    /// Dense slab; index = slot id.
    slots: Vec<Slot>,
    /// Recycled slot ids.
    free: Vec<u32>,
    /// `slot_of[metric_id][group_id]` → slot id (`NO_SLOT` when absent).
    slot_of: Vec<Vec<u32>>,
    /// Clock hand: next slot index the eviction sweep examines.
    hand: usize,
    /// Occupied slots.
    live: usize,
    capacity: usize,
    /// Cache misses that hit the kvstore (observability).
    pub kv_reads: u64,
    /// Write-throughs to the kvstore.
    pub kv_writes: u64,
    /// Clock-sweep evictions (observability).
    pub evictions: u64,
    /// Dirty slots spilled to the kvstore at eviction time
    /// (observability; subset of `kv_writes`).
    pub spills: u64,
    /// When set, updates mark slots dirty instead of writing through.
    deferred: bool,
    /// Dirty slot ids — dense, drained in place, no key bytes cloned.
    dirty: Vec<u32>,
    scratch: Vec<u8>,
}

impl StateStore {
    /// Wrap a kvstore with a `capacity`-slot state cache.
    pub fn new(store: Arc<Store>, capacity: usize) -> StateStore {
        StateStore {
            store,
            slots: Vec::new(),
            free: Vec::new(),
            slot_of: Vec::new(),
            hand: 0,
            live: 0,
            capacity: capacity.max(16),
            kv_reads: 0,
            kv_writes: 0,
            evictions: 0,
            spills: 0,
            deferred: false,
            dirty: Vec::new(),
            scratch: Vec::with_capacity(64),
        }
    }

    /// Enter deferred mode: subsequent [`StateStore::update`]s mark their
    /// slot dirty instead of writing through. Pair with
    /// [`StateStore::end_deferred`].
    pub fn begin_deferred(&mut self) {
        self.deferred = true;
    }

    /// Leave deferred mode, persisting every dirty state once. The dirty
    /// vec is drained in place (no key cloning; capacity is reused by the
    /// next batch). A slot is popped only after its write succeeds, so a
    /// failed persist leaves the remaining slots dirty — eviction still
    /// writes them out and a later `end_deferred` retries them.
    pub fn end_deferred(&mut self) -> Result<()> {
        self.deferred = false;
        while let Some(&id) = self.dirty.last() {
            let slot = &self.slots[id as usize];
            // an evicted-then-recycled slot may appear here with its
            // dirty flag already cleared (spilled at eviction time) or
            // twice (recycled + re-dirtied): the flag is the truth
            if slot.live && slot.dirty {
                self.persist_slot(id)?;
            }
            self.dirty.pop();
        }
        Ok(())
    }

    /// Compose the storage key for `(metric_id, group_key)` — the on-disk
    /// key format, unchanged from the byte-keyed store.
    pub fn compose_key(metric_id: u32, group_key: &[u8]) -> Vec<u8> {
        let mut k = Vec::with_capacity(group_key.len() + 5);
        varint::write_u32(&mut k, metric_id);
        k.extend_from_slice(group_key);
        k
    }

    /// Slot for `(metric_id, group)` if one is live.
    #[inline]
    fn lookup_slot(&self, metric_id: u32, group: GroupId) -> Option<u32> {
        match self
            .slot_of
            .get(metric_id as usize)
            .and_then(|row| row.get(group.0 as usize))
        {
            Some(&s) if s != NO_SLOT => Some(s),
            _ => None,
        }
    }

    /// Resolve `(metric_id, group)` to a slot, loading a spilled state
    /// from the kvstore on miss. With `init` None, a state that exists
    /// neither in the slab nor on disk resolves to `Ok(None)`.
    fn load_slot(
        &mut self,
        metric_id: u32,
        group: GroupId,
        group_key: &[u8],
        init: Option<&mut dyn FnMut() -> AggState>,
    ) -> Result<Option<u32>> {
        if let Some(s) = self.lookup_slot(metric_id, group) {
            // second chance: a touched slot survives the next sweep pass
            self.slots[s as usize].referenced = true;
            return Ok(Some(s));
        }
        // cold path: first touch of this (metric, group) — or reload of a
        // spilled state. The composed key allocated here lives in the
        // slot for every later persist.
        let key = Self::compose_key(metric_id, group_key);
        let state = match self.store.get(&key)? {
            Some(bytes) => {
                self.kv_reads += 1;
                let mut pos = 0;
                AggState::decode(&bytes, &mut pos)?
            }
            None => match init {
                Some(f) => f(),
                None => return Ok(None),
            },
        };
        Ok(Some(self.insert_slot(metric_id, group, key.into_boxed_slice(), state)?))
    }

    fn insert_slot(
        &mut self,
        metric_id: u32,
        group: GroupId,
        key: Box<[u8]>,
        state: AggState,
    ) -> Result<u32> {
        let id = match self.free.pop() {
            Some(id) => {
                let s = &mut self.slots[id as usize];
                s.state = state;
                s.key = key;
                s.metric_id = metric_id;
                s.group_id = group.0;
                s.dirty = false;
                s.live = true;
                s.referenced = true;
                s.pinned = false;
                id
            }
            None => {
                let id = self.slots.len() as u32;
                self.slots.push(Slot {
                    state,
                    key,
                    metric_id,
                    group_id: group.0,
                    dirty: false,
                    live: true,
                    referenced: true,
                    pinned: false,
                });
                id
            }
        };
        let m = metric_id as usize;
        if self.slot_of.len() <= m {
            self.slot_of.resize_with(m + 1, Vec::new);
        }
        let row = &mut self.slot_of[m];
        let g = group.0 as usize;
        if row.len() <= g {
            row.resize(g + 1, NO_SLOT);
        }
        row[g] = id;
        self.live += 1;
        self.evict_over_capacity(id)?;
        Ok(id)
    }

    /// Clock / second-chance sweep: spill + recycle unreferenced slots
    /// until within capacity. Referenced slots get their bit cleared and
    /// one more round in memory; `protect` (the slot being inserted or
    /// reloaded) is never the victim — the caller holds its id.
    fn evict_over_capacity(&mut self, protect: u32) -> Result<()> {
        while self.live > self.capacity {
            let n = self.slots.len();
            let mut victim: Option<u32> = None;
            // first full pass may clear every referenced bit; the second
            // is then guaranteed to find a victim (bounded sweep)
            let mut spins = 0usize;
            while spins <= 2 * n {
                if self.hand >= n {
                    self.hand = 0;
                }
                let id = self.hand as u32;
                self.hand += 1;
                spins += 1;
                if id == protect {
                    continue;
                }
                let slot = &mut self.slots[id as usize];
                if !slot.live || slot.pinned {
                    continue;
                }
                if slot.referenced {
                    slot.referenced = false; // second chance
                    continue;
                }
                victim = Some(id);
                break;
            }
            // only the protected slot is live ⇒ nothing evictable
            let Some(id) = victim else { break };
            // deferred-dirty states must hit the kvstore before the
            // in-memory copy goes away; everything else was persisted by
            // write-through already
            if self.slots[id as usize].dirty {
                self.persist_slot(id)?;
                self.spills += 1;
            }
            self.evictions += 1;
            self.free_slot(id);
        }
        Ok(())
    }

    /// Write a slot's state through to the kvstore, clearing its dirty
    /// flag on success.
    fn persist_slot(&mut self, id: u32) -> Result<()> {
        let slot = &mut self.slots[id as usize];
        self.scratch.clear();
        slot.state.encode(&mut self.scratch);
        self.store.put(&slot.key, &self.scratch)?;
        slot.dirty = false;
        self.kv_writes += 1;
        Ok(())
    }

    /// Release a slot to the free list (caller persists dirty state
    /// first when needed).
    fn free_slot(&mut self, id: u32) {
        let slot = &mut self.slots[id as usize];
        slot.live = false;
        slot.dirty = false;
        slot.referenced = false;
        slot.pinned = false;
        // drop the heavy payloads now, not at recycling time
        slot.state = AggState::new(AggKind::Count);
        slot.key = Box::default();
        let (m, g) = (slot.metric_id as usize, slot.group_id as usize);
        if let Some(e) = self.slot_of.get_mut(m).and_then(|row| row.get_mut(g)) {
            *e = NO_SLOT;
        }
        self.free.push(id);
        self.live -= 1;
    }

    /// Mutate the state for `(metric_id, group)`, creating it with `init`
    /// when absent, then persist (write-through, or dirty-mark in
    /// deferred mode). Returns the post-update aggregate value.
    ///
    /// Hot path: slot resolution is two `Vec` indexings; `group_key` is
    /// only read on the cold path (slot creation / reload after spill).
    pub fn update(
        &mut self,
        metric_id: u32,
        group: GroupId,
        group_key: &[u8],
        mut init: impl FnMut() -> AggState,
        f: impl FnOnce(&mut AggState),
    ) -> Result<Option<f64>> {
        let id = self
            .load_slot(metric_id, group, group_key, Some(&mut init))?
            .expect("load_slot with init always yields a slot");
        let slot = &mut self.slots[id as usize];
        f(&mut slot.state);
        let value = slot.state.value();
        if self.deferred {
            // coalesced write-through: persist once at end_deferred
            if !slot.dirty {
                slot.dirty = true;
                self.dirty.push(id);
            }
        } else {
            self.scratch.clear();
            slot.state.encode(&mut self.scratch);
            self.store.put(&slot.key, &self.scratch)?;
            self.kv_writes += 1;
        }
        Ok(value)
    }

    /// Resolve `(metric_id, group)` to a slot for a gather pass — the
    /// batch path's replacement for per-event [`StateStore::update`]
    /// resolution. Same semantics as the internal load: a spilled state
    /// reloads from the kvstore; with `init` None, a state that exists
    /// nowhere resolves to `Ok(None)`.
    ///
    /// The returned slot is **pinned**: the clock sweep will not evict it
    /// until its gathered run is applied via [`StateStore::apply_run`],
    /// so the caller's slot→run linkage stays valid for the whole batch.
    /// Every pinned slot must therefore see exactly one `apply_run`
    /// before the next insert-heavy workload, or it stays unevictable.
    pub(crate) fn gather_slot(
        &mut self,
        metric_id: u32,
        group: GroupId,
        group_key: &[u8],
        init: Option<&mut dyn FnMut() -> AggState>,
    ) -> Result<Option<u32>> {
        let slot = self.load_slot(metric_id, group, group_key, init)?;
        if let Some(id) = slot {
            self.slots[id as usize].pinned = true;
        }
        Ok(slot)
    }

    /// Apply a gathered run to a pinned slot's state and release the pin.
    /// With `mutated` set the slot then persists exactly like an
    /// [`StateStore::update`] (write-through, or dirty-mark in deferred
    /// mode); a read-only run (every row excluded by null semantics)
    /// skips persistence, like the scalar path's `value()` reads did.
    pub(crate) fn apply_run<R>(
        &mut self,
        id: u32,
        mutated: bool,
        f: impl FnOnce(&mut AggState) -> R,
    ) -> Result<R> {
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.live && slot.pinned, "apply_run on an unpinned slot");
        slot.pinned = false;
        let r = f(&mut slot.state);
        if !mutated {
            return Ok(r);
        }
        if self.deferred {
            // coalesced write-through: persist once at end_deferred
            if !slot.dirty {
                slot.dirty = true;
                self.dirty.push(id);
            }
        } else {
            self.scratch.clear();
            slot.state.encode(&mut self.scratch);
            self.store.put(&slot.key, &self.scratch)?;
            self.kv_writes += 1;
        }
        Ok(r)
    }

    /// Read the current aggregate value for `(metric_id, group)` (no
    /// mutation). Spilled states are reloaded into the slab.
    pub fn value(
        &mut self,
        metric_id: u32,
        group: GroupId,
        group_key: &[u8],
    ) -> Result<Option<f64>> {
        match self.load_slot(metric_id, group, group_key, None)? {
            Some(id) => Ok(self.slots[id as usize].state.value()),
            None => Ok(None),
        }
    }

    /// Read a state straight from the kvstore by key bytes, without an
    /// interned id (query paths over reopened stores; the slab never saw
    /// these groups, so nothing can be dirty in memory).
    pub fn value_by_key(&mut self, metric_id: u32, group_key: &[u8]) -> Result<Option<f64>> {
        let key = Self::compose_key(metric_id, group_key);
        match self.store.get(&key)? {
            Some(bytes) => {
                self.kv_reads += 1;
                let mut pos = 0;
                Ok(AggState::decode(&bytes, &mut pos)?.value())
            }
            None => Ok(None),
        }
    }

    /// Drop every state of a metric (metric deletion / backfill reset).
    pub fn clear_metric(&mut self, metric_id: u32) -> Result<()> {
        if let Some(row) = self.slot_of.get(metric_id as usize) {
            let ids: Vec<u32> = row.iter().copied().filter(|&s| s != NO_SLOT).collect();
            for id in ids {
                self.free_slot(id);
            }
        }
        let mut prefix = Vec::new();
        varint::write_u32(&mut prefix, metric_id);
        for (k, _) in self.store.scan_prefix(&prefix)? {
            self.store.delete(&k)?;
        }
        Ok(())
    }

    /// Flush underlying kvstore (checkpoint barrier).
    pub fn flush(&self) -> Result<()> {
        self.store.flush()
    }

    /// Every persisted state as raw `(composed key, encoded AggState)`
    /// pairs — the checkpoint image. Dirty in-memory slots are persisted
    /// first so the scan sees the current value of every state; the
    /// bytes are exactly what an eviction spill would write, so a
    /// restore is a plain `Store::put` per pair and the slab reloads
    /// lazily through the normal cold path.
    pub fn export_states(&mut self) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let dirty_ids: Vec<u32> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.live && s.dirty)
            .map(|(i, _)| i as u32)
            .collect();
        for id in dirty_ids {
            self.persist_slot(id)?;
        }
        self.store.scan_prefix(&[])
    }

    /// Restore an [`export_states`](Self::export_states) image into the
    /// underlying kvstore. Recovery-time only: the slab must be empty
    /// (no event has been dispatched); restored states are loaded
    /// lazily through the normal cold path on first touch.
    pub fn restore_states(&mut self, pairs: &[(Vec<u8>, Vec<u8>)]) -> Result<()> {
        if self.live != 0 {
            return Err(crate::error::Error::invalid(
                "state restore requires an empty state cache",
            ));
        }
        for (key, value) in pairs {
            self.store.put(key, value)?;
        }
        self.store.flush()
    }

    /// Number of states currently cached in memory.
    pub fn cached_states(&self) -> usize {
        self.live
    }

    /// Capacity of the deferred dirty vec (regression observability: the
    /// buffer must be reused across batches, never rebuilt from cloned
    /// keys).
    pub fn dirty_capacity(&self) -> usize {
        self.dirty.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::StoreOptions;
    use crate::util::tmp::TempDir;

    fn setup(capacity: usize) -> (TempDir, StateStore) {
        let tmp = TempDir::new("statestore");
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        (tmp, StateStore::new(store, capacity))
    }

    fn add(
        ss: &mut StateStore,
        metric: u32,
        group: u32,
        key: &[u8],
        seq: u64,
        v: f64,
    ) -> Option<f64> {
        ss.update(metric, GroupId(group), key, || AggState::new(AggKind::Sum), |st| {
            st.add(seq, v, 0)
        })
        .unwrap()
    }

    #[test]
    fn update_creates_and_accumulates() {
        let (_tmp, mut ss) = setup(100);
        assert_eq!(add(&mut ss, 1, 0, b"card_a", 0, 10.0), Some(10.0));
        assert_eq!(add(&mut ss, 1, 0, b"card_a", 1, 5.0), Some(15.0));
    }

    #[test]
    fn metrics_are_namespaced() {
        let (_tmp, mut ss) = setup(100);
        for m in [1u32, 2] {
            ss.update(m, GroupId(0), b"k", || AggState::new(AggKind::Count), |st| {
                st.add(0, 0.0, 0)
            })
            .unwrap();
        }
        assert_eq!(ss.value(1, GroupId(0), b"k").unwrap(), Some(1.0));
        assert_eq!(ss.value(2, GroupId(0), b"k").unwrap(), Some(1.0));
        assert_eq!(ss.value(3, GroupId(0), b"k").unwrap(), None);
    }

    #[test]
    fn eviction_falls_back_to_kvstore() {
        let (_tmp, mut ss) = setup(16); // tiny cache (min)
        let keys: Vec<String> = (0..200).map(|i| format!("card_{i}")).collect();
        for (i, k) in keys.iter().enumerate() {
            add(&mut ss, 1, i as u32, k.as_bytes(), 0, i as f64);
        }
        assert!(ss.cached_states() <= 16);
        // every state still readable (reloaded from kvstore into the slab)
        for (i, k) in keys.iter().enumerate() {
            let v = ss.value(1, GroupId(i as u32), k.as_bytes()).unwrap();
            assert_eq!(v, Some(i as f64), "card_{i}");
        }
        assert!(ss.kv_reads > 0, "evicted states were re-read");
    }

    #[test]
    fn update_after_eviction_resumes_from_persisted_state() {
        let (_tmp, mut ss) = setup(16);
        add(&mut ss, 1, 0, b"victim", 0, 7.0);
        // push it out of the cache
        for i in 0..50u32 {
            add(&mut ss, 1, i + 1, format!("filler_{i}").as_bytes(), 0, 1.0);
        }
        let v = add(&mut ss, 1, 0, b"victim", 1, 3.0);
        assert_eq!(v, Some(10.0), "accumulated across eviction");
    }

    #[test]
    fn clear_metric_removes_only_that_metric() {
        let (_tmp, mut ss) = setup(100);
        for m in [1u32, 2] {
            ss.update(m, GroupId(0), b"k", || AggState::new(AggKind::Count), |st| {
                st.add(0, 0.0, 0)
            })
            .unwrap();
        }
        ss.clear_metric(1).unwrap();
        assert_eq!(ss.value(1, GroupId(0), b"k").unwrap(), None);
        assert_eq!(ss.value(2, GroupId(0), b"k").unwrap(), Some(1.0));
    }

    #[test]
    fn deferred_mode_coalesces_writes() {
        let (_tmp, mut ss) = setup(100);
        ss.begin_deferred();
        for i in 0..50u64 {
            add(&mut ss, 1, 0, b"hot_key", i, 1.0);
        }
        assert_eq!(ss.kv_writes, 0, "writes deferred during the batch");
        ss.end_deferred().unwrap();
        assert_eq!(ss.kv_writes, 1, "one coalesced write for the hot key");
        assert_eq!(ss.value(1, GroupId(0), b"hot_key").unwrap(), Some(50.0));
        // back in write-through mode
        add(&mut ss, 1, 0, b"hot_key", 50, 1.0);
        assert_eq!(ss.kv_writes, 2);
    }

    #[test]
    fn deferred_state_survives_reopen() {
        let tmp = TempDir::new("statestore_deferred_reopen");
        {
            let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
            let mut ss = StateStore::new(store, 100);
            ss.begin_deferred();
            add(&mut ss, 3, 0, b"k", 0, 5.0);
            ss.end_deferred().unwrap();
            ss.flush().unwrap();
        }
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        let mut ss = StateStore::new(store, 100);
        // a fresh slab reloads the persisted state (the group id is
        // irrelevant to the on-disk key)
        assert_eq!(ss.value(3, GroupId(9), b"k").unwrap(), Some(5.0));
        assert_eq!(ss.value_by_key(3, b"k").unwrap(), Some(5.0));
    }

    #[test]
    fn deferred_dirty_entry_evicted_is_persisted() {
        let (_tmp, mut ss) = setup(16); // min capacity
        ss.begin_deferred();
        add(&mut ss, 1, 0, b"victim", 0, 7.0);
        // push the victim out of the cache while still dirty
        for i in 0..50u32 {
            add(&mut ss, 1, i + 1, format!("filler_{i}").as_bytes(), 0, 1.0);
        }
        ss.end_deferred().unwrap();
        assert_eq!(ss.value(1, GroupId(0), b"victim").unwrap(), Some(7.0));
    }

    #[test]
    fn end_deferred_drains_in_place_without_key_clones() {
        // Regression for the pre-slab store, which cloned every dirty key
        // into a Vec<Vec<u8>> per batch. The dirty set is now a dense
        // Vec<u32> of slot ids: draining pops in place and the buffer's
        // capacity is reused by the next batch — no per-batch growth, no
        // key bytes moved, by construction.
        let (_tmp, mut ss) = setup(1000);
        let keys: Vec<String> = (0..100).map(|i| format!("g{i}")).collect();
        let run_batch = |ss: &mut StateStore, seq: u64| {
            ss.begin_deferred();
            for (i, k) in keys.iter().enumerate() {
                add(ss, 1, i as u32, k.as_bytes(), seq, 1.0);
            }
            ss.end_deferred().unwrap();
        };
        run_batch(&mut ss, 0);
        let warm_capacity = ss.dirty_capacity();
        assert!(warm_capacity >= keys.len());
        let writes_after_warmup = ss.kv_writes;
        for seq in 1..5u64 {
            run_batch(&mut ss, seq);
            assert_eq!(ss.dirty_capacity(), warm_capacity, "dirty buffer reused");
        }
        assert_eq!(
            ss.kv_writes - writes_after_warmup,
            4 * keys.len() as u64,
            "one coalesced write per dirty state per batch"
        );
    }

    #[test]
    fn recycled_slots_keep_states_independent() {
        // force heavy eviction so slot ids are recycled across groups,
        // then verify no state bleeds between (metric, group) pairs
        let (_tmp, mut ss) = setup(16);
        for round in 0..3u64 {
            for i in 0..40u32 {
                add(&mut ss, 1, i, format!("g{i}").as_bytes(), round, (i + 1) as f64);
            }
        }
        for i in 0..40u32 {
            assert_eq!(
                ss.value(1, GroupId(i), format!("g{i}").as_bytes()).unwrap(),
                Some(3.0 * (i + 1) as f64),
                "g{i}"
            );
        }
    }

    #[test]
    fn clock_eviction_keeps_hot_groups_resident() {
        // Regression for the insertion-order approximate LRU, which
        // evicted purely by slot age: a group touched on every batch
        // still got spilled once enough younger groups arrived. The
        // clock sweep gives touched slots a second chance, so the hot
        // group must stay in the slab through heavy filler churn.
        let (_tmp, mut ss) = setup(16);
        add(&mut ss, 1, 0, b"hot", 0, 1.0);
        let mut seq = 1u64;
        for round in 0..10u32 {
            for i in 0..12u32 {
                let g = 1 + round * 12 + i;
                add(&mut ss, 1, g, format!("filler_{g}").as_bytes(), seq, 1.0);
                seq += 1;
            }
            // touch the hot group between filler waves (sets its
            // referenced bit — under insertion-order LRU this was a
            // no-op and the hot group aged out)
            add(&mut ss, 1, 0, b"hot", seq, 1.0);
            seq += 1;
        }
        let reads_before = ss.kv_reads;
        assert_eq!(ss.value(1, GroupId(0), b"hot").unwrap(), Some(11.0));
        assert_eq!(
            ss.kv_reads, reads_before,
            "hot group must still be resident (no kvstore reload)"
        );
    }

    #[test]
    fn clock_eviction_stays_within_capacity_under_churn() {
        let (_tmp, mut ss) = setup(16);
        for i in 0..500u32 {
            add(&mut ss, 1, i, format!("g{i}").as_bytes(), 0, (i + 1) as f64);
            assert!(ss.cached_states() <= 16);
        }
        // every spilled state is still correct when reloaded
        for i in (0..500u32).step_by(97) {
            assert_eq!(
                ss.value(1, GroupId(i), format!("g{i}").as_bytes()).unwrap(),
                Some((i + 1) as f64),
                "g{i}"
            );
        }
    }

    #[test]
    fn pinned_slots_survive_the_eviction_sweep() {
        let (_tmp, mut ss) = setup(16);
        let mut init = || AggState::new(AggKind::Sum);
        let pinned = ss
            .gather_slot(1, GroupId(0), b"pinned", Some(&mut init))
            .unwrap()
            .expect("init always yields a slot");
        // flood the cache far past capacity: the pinned slot is the
        // oldest, coldest slot, yet must never be chosen as a victim
        for i in 0..100u32 {
            add(&mut ss, 1, i + 1, format!("filler_{i}").as_bytes(), 0, 1.0);
        }
        assert!(ss.cached_states() <= 16);
        // the slot is still live and holds the same state: applying the
        // deferred run lands on it, then releases the pin
        ss.apply_run(pinned, true, |st| st.add(0, 4.0, 0)).unwrap();
        assert_eq!(ss.value(1, GroupId(0), b"pinned").unwrap(), Some(4.0));
        // unpinned now: heavy churn may spill it like any other slot,
        // and the persisted state must survive the round-trip
        for i in 0..100u32 {
            add(&mut ss, 1, i + 101, format!("late_{i}").as_bytes(), 0, 1.0);
        }
        assert_eq!(ss.value(1, GroupId(0), b"pinned").unwrap(), Some(4.0));
    }

    #[test]
    fn apply_run_without_mutation_skips_persistence() {
        let (_tmp, mut ss) = setup(100);
        add(&mut ss, 1, 0, b"k", 0, 2.5);
        let writes = ss.kv_writes;
        let slot = ss.gather_slot(1, GroupId(0), b"k", None).unwrap().unwrap();
        let v = ss.apply_run(slot, false, |st| st.value()).unwrap();
        assert_eq!(v, Some(2.5));
        assert_eq!(ss.kv_writes, writes, "read-only run writes nothing");
    }

    #[test]
    fn persists_across_reopen() {
        let tmp = TempDir::new("statestore_reopen");
        {
            let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
            let mut ss = StateStore::new(store, 100);
            add(&mut ss, 7, 0, b"card_z", 0, 42.0);
            ss.flush().unwrap();
        }
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        let mut ss = StateStore::new(store, 100);
        assert_eq!(ss.value(7, GroupId(0), b"card_z").unwrap(), Some(42.0));
    }
}
