//! The aggregation **state store** (paper §3.3.2): kvstore-backed
//! persistence with a bounded in-memory cache.
//!
//! Keys are `varint(metric_id) ++ group_key_bytes`. Updates are
//! write-through: the hot path mutates the cached state and appends the
//! encoded state to the kvstore (WAL + memtable — no fsync, no disk read).
//! The cache is sized in entries; eviction drops the in-memory copy only
//! (the kvstore holds the durable truth), which bounds memory even with
//! unbounded group-by cardinality.
//!
//! **Deferred mode** ([`StateStore::begin_deferred`] /
//! [`StateStore::end_deferred`]) coalesces write-throughs across a batch
//! of events: updates only mark their key dirty, and the batch end
//! persists each dirty state **once** — a group touched by many events
//! of a batch pays one kvstore write instead of one per event. Eviction
//! of a dirty entry persists it first, so the kvstore never lags the
//! cache for states that leave memory.

use crate::agg::AggState;
use crate::error::Result;
use crate::kvstore::Store;
use crate::util::hash::{FxHashMap, FxHashSet};
use crate::util::varint;
use std::collections::VecDeque;
use std::sync::Arc;

/// Cached, persistent aggregation states.
pub struct StateStore {
    store: Arc<Store>,
    cache: FxHashMap<Vec<u8>, AggState>,
    /// Insertion-order queue for cheap approximate-LRU eviction.
    order: VecDeque<Vec<u8>>,
    capacity: usize,
    /// Cache misses that hit the kvstore (observability).
    pub kv_reads: u64,
    /// Write-throughs to the kvstore.
    pub kv_writes: u64,
    /// When set, updates mark keys dirty instead of writing through.
    deferred: bool,
    /// Keys updated since the deferral began.
    dirty: FxHashSet<Vec<u8>>,
    scratch: Vec<u8>,
    key_scratch: Vec<u8>,
}

impl StateStore {
    /// Wrap a kvstore with an `capacity`-entry state cache.
    pub fn new(store: Arc<Store>, capacity: usize) -> StateStore {
        StateStore {
            store,
            cache: FxHashMap::default(),
            order: VecDeque::new(),
            capacity: capacity.max(16),
            kv_reads: 0,
            kv_writes: 0,
            deferred: false,
            dirty: FxHashSet::default(),
            scratch: Vec::with_capacity(64),
            key_scratch: Vec::with_capacity(64),
        }
    }

    /// Enter deferred mode: subsequent [`StateStore::update`]s mark their
    /// key dirty instead of writing through. Pair with
    /// [`StateStore::end_deferred`].
    pub fn begin_deferred(&mut self) {
        self.deferred = true;
    }

    /// Leave deferred mode, persisting every dirty state once. A key is
    /// un-marked only after its write succeeds, so a failed persist
    /// leaves the remaining keys dirty — eviction still writes them out
    /// and a later `end_deferred` retries them.
    pub fn end_deferred(&mut self) -> Result<()> {
        self.deferred = false;
        let keys: Vec<Vec<u8>> = self.dirty.iter().cloned().collect();
        for key in keys {
            self.persist(&key)?;
            self.dirty.remove(&key);
        }
        Ok(())
    }

    /// Write the cached state for `key` through to the kvstore (no-op if
    /// the key is not cached — an evicted dirty key was persisted at
    /// eviction time).
    fn persist(&mut self, key: &[u8]) -> Result<()> {
        if let Some(st) = self.cache.get(key) {
            self.scratch.clear();
            st.encode(&mut self.scratch);
        } else {
            return Ok(());
        }
        self.store.put(key, &self.scratch)?;
        self.kv_writes += 1;
        Ok(())
    }

    /// Compose the storage key for `(metric_id, group_key)`.
    pub fn compose_key(metric_id: u32, group_key: &[u8]) -> Vec<u8> {
        let mut k = Vec::with_capacity(group_key.len() + 5);
        varint::write_u32(&mut k, metric_id);
        k.extend_from_slice(group_key);
        k
    }

    /// Mutate the state for a key, creating it with `init` when absent,
    /// then persist. Returns the post-update aggregate value.
    ///
    /// Hot path: the composed key lives in a reused scratch buffer and is
    /// only heap-allocated when a new cache entry is inserted
    /// (EXPERIMENTS.md §Perf).
    pub fn update(
        &mut self,
        metric_id: u32,
        group_key: &[u8],
        init: impl FnOnce() -> AggState,
        f: impl FnOnce(&mut AggState),
    ) -> Result<Option<f64>> {
        self.key_scratch.clear();
        varint::write_u32(&mut self.key_scratch, metric_id);
        self.key_scratch.extend_from_slice(group_key);
        if !self.cache.contains_key(self.key_scratch.as_slice()) {
            let loaded = match self.store.get(&self.key_scratch)? {
                Some(bytes) => {
                    self.kv_reads += 1;
                    let mut pos = 0;
                    AggState::decode(&bytes, &mut pos)?
                }
                None => init(),
            };
            let key = self.key_scratch.clone();
            self.insert_cached(key, loaded)?;
        }
        let st = self
            .cache
            .get_mut(self.key_scratch.as_slice())
            .expect("just inserted");
        f(st);
        let value = st.value();
        if self.deferred {
            // coalesced write-through: persist once at end_deferred
            if !self.dirty.contains(self.key_scratch.as_slice()) {
                self.dirty.insert(self.key_scratch.clone());
            }
        } else {
            // write-through
            self.scratch.clear();
            st.encode(&mut self.scratch);
            self.store.put(&self.key_scratch, &self.scratch)?;
            self.kv_writes += 1;
        }
        Ok(value)
    }

    /// Read the current aggregate value (no mutation).
    pub fn value(&mut self, metric_id: u32, group_key: &[u8]) -> Result<Option<f64>> {
        let key = Self::compose_key(metric_id, group_key);
        if let Some(st) = self.cache.get(&key) {
            return Ok(st.value());
        }
        match self.store.get(&key)? {
            Some(bytes) => {
                self.kv_reads += 1;
                let mut pos = 0;
                let st = AggState::decode(&bytes, &mut pos)?;
                let v = st.value();
                self.insert_cached(key, st)?;
                Ok(v)
            }
            None => Ok(None),
        }
    }

    /// Drop every state of a metric (metric deletion / backfill reset).
    pub fn clear_metric(&mut self, metric_id: u32) -> Result<()> {
        let prefix = {
            let mut p = Vec::new();
            varint::write_u32(&mut p, metric_id);
            p
        };
        self.cache.retain(|k, _| !k.starts_with(&prefix));
        self.dirty.retain(|k| !k.starts_with(&prefix));
        for (k, _) in self.store.scan_prefix(&prefix)? {
            self.store.delete(&k)?;
        }
        Ok(())
    }

    /// Flush underlying kvstore (checkpoint barrier).
    pub fn flush(&self) -> Result<()> {
        self.store.flush()
    }

    /// Number of states currently cached in memory.
    pub fn cached_states(&self) -> usize {
        self.cache.len()
    }

    fn insert_cached(&mut self, key: Vec<u8>, st: AggState) -> Result<()> {
        self.cache.insert(key.clone(), st);
        self.order.push_back(key);
        while self.cache.len() > self.capacity {
            if let Some(old) = self.order.pop_front() {
                // deferred-dirty entries must hit the kvstore before the
                // in-memory copy goes away; everything else was
                // write-through persisted already
                if self.dirty.remove(&old) {
                    self.persist(&old)?;
                }
                self.cache.remove(&old);
            } else {
                break;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::AggKind;
    use crate::kvstore::StoreOptions;
    use crate::util::tmp::TempDir;

    fn setup(capacity: usize) -> (TempDir, StateStore) {
        let tmp = TempDir::new("statestore");
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        (tmp, StateStore::new(store, capacity))
    }

    #[test]
    fn update_creates_and_accumulates() {
        let (_tmp, mut ss) = setup(100);
        let v = ss
            .update(1, b"card_a", || AggState::new(AggKind::Sum), |st| {
                st.add(0, 10.0, 0)
            })
            .unwrap();
        assert_eq!(v, Some(10.0));
        let v = ss
            .update(1, b"card_a", || AggState::new(AggKind::Sum), |st| {
                st.add(1, 5.0, 0)
            })
            .unwrap();
        assert_eq!(v, Some(15.0));
    }

    #[test]
    fn metrics_are_namespaced() {
        let (_tmp, mut ss) = setup(100);
        ss.update(1, b"k", || AggState::new(AggKind::Count), |st| {
            st.add(0, 0.0, 0)
        })
        .unwrap();
        ss.update(2, b"k", || AggState::new(AggKind::Count), |st| {
            st.add(0, 0.0, 0)
        })
        .unwrap();
        assert_eq!(ss.value(1, b"k").unwrap(), Some(1.0));
        assert_eq!(ss.value(2, b"k").unwrap(), Some(1.0));
        assert_eq!(ss.value(3, b"k").unwrap(), None);
    }

    #[test]
    fn eviction_falls_back_to_kvstore() {
        let (_tmp, mut ss) = setup(16); // tiny cache (min)
        for i in 0..200u32 {
            ss.update(
                1,
                format!("card_{i}").as_bytes(),
                || AggState::new(AggKind::Sum),
                |st| st.add(0, i as f64, 0),
            )
            .unwrap();
        }
        assert!(ss.cached_states() <= 16);
        // every state still readable (from kvstore)
        for i in 0..200u32 {
            let v = ss.value(1, format!("card_{i}").as_bytes()).unwrap();
            assert_eq!(v, Some(i as f64), "card_{i}");
        }
        assert!(ss.kv_reads > 0, "evicted states were re-read");
    }

    #[test]
    fn update_after_eviction_resumes_from_persisted_state() {
        let (_tmp, mut ss) = setup(16);
        ss.update(1, b"victim", || AggState::new(AggKind::Sum), |st| {
            st.add(0, 7.0, 0)
        })
        .unwrap();
        // push it out of the cache
        for i in 0..50u32 {
            ss.update(
                1,
                format!("filler_{i}").as_bytes(),
                || AggState::new(AggKind::Sum),
                |st| st.add(0, 1.0, 0),
            )
            .unwrap();
        }
        let v = ss
            .update(1, b"victim", || AggState::new(AggKind::Sum), |st| {
                st.add(1, 3.0, 0)
            })
            .unwrap();
        assert_eq!(v, Some(10.0), "accumulated across eviction");
    }

    #[test]
    fn clear_metric_removes_only_that_metric() {
        let (_tmp, mut ss) = setup(100);
        for m in [1u32, 2] {
            ss.update(m, b"k", || AggState::new(AggKind::Count), |st| {
                st.add(0, 0.0, 0)
            })
            .unwrap();
        }
        ss.clear_metric(1).unwrap();
        assert_eq!(ss.value(1, b"k").unwrap(), None);
        assert_eq!(ss.value(2, b"k").unwrap(), Some(1.0));
    }

    #[test]
    fn deferred_mode_coalesces_writes() {
        let (_tmp, mut ss) = setup(100);
        ss.begin_deferred();
        for i in 0..50u64 {
            ss.update(1, b"hot_key", || AggState::new(AggKind::Sum), |st| {
                st.add(i, 1.0, 0)
            })
            .unwrap();
        }
        assert_eq!(ss.kv_writes, 0, "writes deferred during the batch");
        ss.end_deferred().unwrap();
        assert_eq!(ss.kv_writes, 1, "one coalesced write for the hot key");
        assert_eq!(ss.value(1, b"hot_key").unwrap(), Some(50.0));
        // back in write-through mode
        ss.update(1, b"hot_key", || AggState::new(AggKind::Sum), |st| {
            st.add(50, 1.0, 0)
        })
        .unwrap();
        assert_eq!(ss.kv_writes, 2);
    }

    #[test]
    fn deferred_state_survives_reopen() {
        let tmp = TempDir::new("statestore_deferred_reopen");
        {
            let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
            let mut ss = StateStore::new(store, 100);
            ss.begin_deferred();
            ss.update(3, b"k", || AggState::new(AggKind::Sum), |st| {
                st.add(0, 5.0, 0)
            })
            .unwrap();
            ss.end_deferred().unwrap();
            ss.flush().unwrap();
        }
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        let mut ss = StateStore::new(store, 100);
        assert_eq!(ss.value(3, b"k").unwrap(), Some(5.0));
    }

    #[test]
    fn deferred_dirty_entry_evicted_is_persisted() {
        let (_tmp, mut ss) = setup(16); // min capacity
        ss.begin_deferred();
        ss.update(1, b"victim", || AggState::new(AggKind::Sum), |st| {
            st.add(0, 7.0, 0)
        })
        .unwrap();
        // push the victim out of the cache while still dirty
        for i in 0..50u32 {
            ss.update(
                1,
                format!("filler_{i}").as_bytes(),
                || AggState::new(AggKind::Sum),
                |st| st.add(0, 1.0, 0),
            )
            .unwrap();
        }
        ss.end_deferred().unwrap();
        assert_eq!(ss.value(1, b"victim").unwrap(), Some(7.0));
    }

    #[test]
    fn persists_across_reopen() {
        let tmp = TempDir::new("statestore_reopen");
        {
            let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
            let mut ss = StateStore::new(store, 100);
            ss.update(7, b"card_z", || AggState::new(AggKind::Sum), |st| {
                st.add(0, 42.0, 0)
            })
            .unwrap();
            ss.flush().unwrap();
        }
        let store = Arc::new(Store::open(tmp.path(), StoreOptions::default()).unwrap());
        let mut ss = StateStore::new(store, 100);
        assert_eq!(ss.value(7, b"card_z").unwrap(), Some(42.0));
    }
}
