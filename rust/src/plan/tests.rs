//! Plan DAG tests: semantics vs a brute-force oracle, prefix sharing,
//! iterator sharing, backfill.

use super::*;
use crate::agg::AggKind;
use crate::event::{FieldType, Schema, SchemaRef};
use crate::kvstore::{Store, StoreOptions};
use crate::reservoir::{Reservoir, ReservoirConfig};
use crate::util::clock::ms;
use crate::util::rng::Rng;
use crate::util::tmp::TempDir;
use std::sync::Arc;

fn schema() -> SchemaRef {
    Schema::of(&[
        ("card", FieldType::Str),
        ("merchant", FieldType::Str),
        ("amount", FieldType::F64),
    ])
    .unwrap()
}

fn ev(ts: i64, card: &str, merchant: &str, amount: f64) -> Event {
    Event::new(
        ts,
        vec![
            Value::Str(card.into()),
            Value::Str(merchant.into()),
            Value::F64(amount),
        ],
    )
}

struct Rig {
    _tmp: TempDir,
    reservoir: Reservoir,
    plan: Plan,
}

fn rig(specs: &[MetricSpec]) -> Rig {
    let tmp = TempDir::new("plan");
    let rcfg = ReservoirConfig {
        chunk_events: 16,
        cache_chunks: 8,
        ..ReservoirConfig::new(tmp.join("reservoir"))
    };
    let reservoir = Reservoir::open(rcfg, schema()).unwrap();
    let store = Arc::new(Store::open(&tmp.join("state"), StoreOptions::default()).unwrap());
    let state = StateStore::new(store, 10_000);
    let plan = Plan::build(schema(), specs, &reservoir, state).unwrap();
    Rig {
        _tmp: tmp,
        reservoir,
        plan,
    }
}

impl Rig {
    /// Append + advance, the per-event cycle of a task processor.
    fn feed(&mut self, e: Event) -> Vec<ResolvedReply> {
        let t_eval = e.timestamp + 1;
        self.reservoir.append(&e).unwrap();
        self.plan.advance(t_eval).unwrap()
    }
}

fn q1_specs() -> Vec<MetricSpec> {
    // the paper's Example 1
    vec![
        MetricSpec::new(
            "sum_amount_by_card",
            AggKind::Sum,
            Some("amount"),
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "count_by_card",
            AggKind::Count,
            None,
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "avg_amount_by_merchant",
            AggKind::Avg,
            Some("amount"),
            WindowSpec::sliding(5 * ms::MINUTE),
            &["merchant"],
        ),
    ]
}

#[test]
fn example1_dag_shares_prefix() {
    let r = rig(&q1_specs());
    // Figure 4: one window, one filter (none), two group nodes, three aggs
    assert_eq!(r.plan.node_counts(), (1, 1, 2, 3));
    // Figure 3: shared tail (offset 0) + shared head (offset 5min) = 2
    assert_eq!(r.plan.iterator_count(), 2);
}

#[test]
fn per_event_values_match_query() {
    let mut r = rig(&q1_specs());
    let m = ms::MINUTE;
    let replies = r.feed(ev(0, "c1", "m1", 10.0));
    assert_eq!(replies.len(), 3);
    let sum = replies
        .iter()
        .find(|x| x.metric == "sum_amount_by_card")
        .unwrap();
    assert_eq!(sum.value, Some(10.0));
    assert_eq!(sum.group, "c1");

    let replies = r.feed(ev(m, "c1", "m2", 5.0));
    let sum = replies
        .iter()
        .find(|x| x.metric == "sum_amount_by_card")
        .unwrap();
    assert_eq!(sum.value, Some(15.0));

    // different card: independent group
    let replies = r.feed(ev(m + 1, "c2", "m1", 100.0));
    let sum = replies
        .iter()
        .find(|x| x.metric == "sum_amount_by_card")
        .unwrap();
    assert_eq!(sum.value, Some(100.0));
    assert_eq!(sum.group, "c2");
}

#[test]
fn events_expire_exactly_at_window_boundary() {
    let mut r = rig(&q1_specs());
    let m = ms::MINUTE;
    r.feed(ev(0, "c1", "m1", 10.0));
    r.feed(ev(m, "c1", "m1", 20.0));
    // at 5:00 + 1ms the event at 0:00 is out (T−w ≤ t < T with w=5min)
    let replies = r.feed(ev(5 * m, "c1", "m1", 1.0));
    let sum = replies
        .iter()
        .find(|x| x.metric == "sum_amount_by_card")
        .unwrap();
    assert_eq!(sum.value, Some(21.0), "event at t=0 expired, t=1min alive");

    let replies = r.feed(ev(6 * m, "c1", "m1", 1.0));
    let sum = replies
        .iter()
        .find(|x| x.metric == "sum_amount_by_card")
        .unwrap();
    assert_eq!(sum.value, Some(2.0), "event at 1min expired too");
}

#[test]
fn figure1_rule_triggers_on_fifth_event() {
    // count(*) per card over 5 minutes; rule: block when count > 4
    let specs = vec![MetricSpec::new(
        "tx_count",
        AggKind::Count,
        None,
        WindowSpec::sliding(5 * ms::MINUTE),
        &["card"],
    )];
    let mut r = rig(&specs);
    let m = ms::MINUTE;
    let times = [30_000, m + 30_000, 2 * m + 30_000, 3 * m + 30_000, 5 * m + 15_000];
    let mut counts = Vec::new();
    for t in times {
        let replies = r.feed(ev(t, "c1", "m1", 1.0));
        counts.push(replies[0].value.unwrap());
    }
    assert_eq!(counts, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    assert!(counts[4] > 4.0, "real sliding window catches the attack");
}

#[test]
fn filter_is_applied_and_shared() {
    let big = FilterExpr::cmp("amount", CmpOp::Gt, Value::F64(50.0));
    let specs = vec![
        MetricSpec::new(
            "big_sum",
            AggKind::Sum,
            Some("amount"),
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        )
        .with_filter(big.clone()),
        MetricSpec::new(
            "big_count",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        )
        .with_filter(big),
        MetricSpec::new(
            "all_count",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
    ];
    let mut r = rig(&specs);
    // shared window; two filter nodes (Some + None); group nodes under each
    assert_eq!(r.plan.node_counts().0, 1);
    assert_eq!(r.plan.node_counts().1, 2);

    r.feed(ev(0, "c1", "m1", 10.0)); // fails filter
    let replies = r.feed(ev(1, "c1", "m1", 60.0)); // passes
    let big_sum = replies.iter().find(|x| x.metric == "big_sum").unwrap();
    assert_eq!(big_sum.value, Some(60.0), "only the 60 counted");
    let all = replies.iter().find(|x| x.metric == "all_count").unwrap();
    assert_eq!(all.value, Some(2.0));
    // filtered-out event produced no reply for filtered metrics
    let first = r.plan.value_for("big_count", &[Value::Str("c1".into())]).unwrap();
    assert_eq!(first, Some(1.0));
}

#[test]
fn misaligned_windows_do_not_share_iterators() {
    let specs = vec![
        MetricSpec::new(
            "m0",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "m1",
            AggKind::Count,
            None,
            WindowSpec::sliding_delayed(ms::MINUTE, 10_000),
            &["card"],
        ),
        MetricSpec::new(
            "m2",
            AggKind::Count,
            None,
            WindowSpec::sliding_delayed(ms::MINUTE, 20_000),
            &["card"],
        ),
    ];
    let r = rig(&specs);
    // 3 windows × 2 iterators, nothing aligns
    assert_eq!(r.plan.iterator_count(), 6);
}

#[test]
fn aligned_heads_and_tails_share() {
    let specs = vec![
        MetricSpec::new(
            "w1",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "w5",
            AggKind::Count,
            None,
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
        // delayed by 1min with 4min size: head at 5min aligns with w5's head
        MetricSpec::new(
            "w4d1",
            AggKind::Count,
            None,
            WindowSpec::sliding_delayed(4 * ms::MINUTE, ms::MINUTE),
            &["card"],
        ),
    ];
    let r = rig(&specs);
    // offsets: tails {0, 0, 1min}, heads {1min, 5min, 5min}
    // distinct: {0, 1min, 5min} = 3 iterators
    assert_eq!(r.plan.iterator_count(), 3);
}

#[test]
fn delayed_window_values_lag() {
    let specs = vec![
        MetricSpec::new(
            "live",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "delayed",
            AggKind::Count,
            None,
            WindowSpec::sliding_delayed(ms::MINUTE, 30_000),
            &["card"],
        ),
    ];
    let mut r = rig(&specs);
    r.feed(ev(0, "c1", "m1", 1.0));
    r.feed(ev(10_000, "c1", "m1", 1.0));
    // delayed window [T-90s, T-30s) at T=10s: empty
    assert_eq!(
        r.plan.value_for("delayed", &[Value::Str("c1".into())]).unwrap(),
        None
    );
    r.feed(ev(45_000, "c1", "m1", 1.0));
    // at T=45s+1: delayed covers [−45s, 15s) ⇒ events at 0 and 10s
    assert_eq!(
        r.plan.value_for("delayed", &[Value::Str("c1".into())]).unwrap(),
        Some(2.0)
    );
    assert_eq!(
        r.plan.value_for("live", &[Value::Str("c1".into())]).unwrap(),
        Some(3.0),
        "live 1-min window [T-60s, T) still holds all three events"
    );
}

#[test]
fn brute_force_oracle_randomized() {
    let specs = vec![
        MetricSpec::new(
            "sum5",
            AggKind::Sum,
            Some("amount"),
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "min5",
            AggKind::Min,
            Some("amount"),
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "distinct_merchants",
            AggKind::CountDistinct,
            Some("merchant"),
            WindowSpec::sliding(5 * ms::MINUTE),
            &["card"],
        ),
    ];
    let mut r = rig(&specs);
    let mut rng = Rng::new(42);
    let mut history: Vec<Event> = Vec::new();
    let mut ts = 0i64;
    for _ in 0..600 {
        ts += rng.range_i64(1, 40_000); // up to 40s apart
        let card = format!("c{}", rng.next_below(4));
        let merchant = format!("m{}", rng.next_below(3));
        let amount = (rng.next_below(1000) as f64) / 10.0;
        let e = ev(ts, &card, &merchant, amount);
        history.push(e.clone());
        let replies = r.feed(e);
        let t_eval = ts + 1;
        let live: Vec<&Event> = history
            .iter()
            .filter(|h| {
                t_eval - 5 * ms::MINUTE <= h.timestamp
                    && h.timestamp < t_eval
                    && h.values[0].as_str() == Some(card.as_str())
            })
            .collect();
        let sum: f64 = live.iter().filter_map(|h| h.values[2].as_f64()).sum();
        let min = live
            .iter()
            .filter_map(|h| h.values[2].as_f64())
            .fold(f64::INFINITY, f64::min);
        let distinct = live
            .iter()
            .filter_map(|h| h.values[1].as_str())
            .collect::<std::collections::HashSet<_>>()
            .len();
        let got_sum = replies.iter().find(|x| x.metric == "sum5").unwrap();
        assert!(
            (got_sum.value.unwrap() - sum).abs() < 1e-6,
            "sum at ts={ts}: got {:?} want {sum}",
            got_sum.value
        );
        let got_min = replies.iter().find(|x| x.metric == "min5").unwrap();
        assert_eq!(got_min.value, Some(min), "min at ts={ts}");
        let got_d = replies
            .iter()
            .find(|x| x.metric == "distinct_merchants")
            .unwrap();
        assert_eq!(got_d.value, Some(distinct as f64), "distinct at ts={ts}");
    }
}

#[test]
fn backfill_matches_never_removed_metric() {
    let base = MetricSpec::new(
        "from_start",
        AggKind::Sum,
        Some("amount"),
        WindowSpec::sliding(5 * ms::MINUTE),
        &["card"],
    );
    let mut r = rig(&[base]);
    let m = ms::MINUTE;
    for i in 0..50 {
        let card = if i % 2 == 0 { "c1" } else { "c2" };
        r.feed(ev(i * 10_000, card, "m1", i as f64));
    }
    // add the same-shaped metric later with backfill
    let late = MetricSpec::new(
        "added_late",
        AggKind::Sum,
        Some("amount"),
        WindowSpec::sliding(5 * ms::MINUTE),
        &["card"],
    );
    r.plan.add_metric_backfill(&late, &r.reservoir).unwrap();
    for card in ["c1", "c2"] {
        let a = r
            .plan
            .value_for("from_start", &[Value::Str(card.into())])
            .unwrap();
        let b = r
            .plan
            .value_for("added_late", &[Value::Str(card.into())])
            .unwrap();
        assert_eq!(a, b, "backfilled metric equals always-on metric ({card})");
    }
    // and it keeps tracking correctly forward
    let replies = r.feed(ev(50 * 10_000 + m, "c1", "m1", 7.5));
    let a = replies.iter().find(|x| x.metric == "from_start").unwrap();
    let b = replies.iter().find(|x| x.metric == "added_late").unwrap();
    assert_eq!(a.value, b.value);
}

#[test]
fn registration_errors() {
    let r = rig(&q1_specs());
    let mut plan = r.plan;
    // duplicate name
    assert!(plan
        .register(
            &MetricSpec::new(
                "sum_amount_by_card",
                AggKind::Sum,
                Some("amount"),
                WindowSpec::sliding(1000),
                &["card"],
            ),
            &r.reservoir,
        )
        .is_err());
    // missing field for SUM
    assert!(plan
        .register(
            &MetricSpec::new("x", AggKind::Sum, None, WindowSpec::sliding(1000), &["card"]),
            &r.reservoir,
        )
        .is_err());
    // unknown field
    assert!(plan
        .register(
            &MetricSpec::new(
                "y",
                AggKind::Sum,
                Some("nope"),
                WindowSpec::sliding(1000),
                &["card"],
            ),
            &r.reservoir,
        )
        .is_err());
    // bad window
    assert!(plan
        .register(
            &MetricSpec::new("z", AggKind::Count, None, WindowSpec::sliding(0), &["card"]),
            &r.reservoir,
        )
        .is_err());
}

#[test]
fn advance_rejects_time_regression() {
    let mut r = rig(&q1_specs());
    r.feed(ev(1000, "c1", "m1", 1.0));
    assert!(r.plan.advance(500).is_err());
}

#[test]
fn global_aggregate_empty_group_by() {
    let specs = vec![MetricSpec::new(
        "total",
        AggKind::Count,
        None,
        WindowSpec::sliding(ms::MINUTE),
        &[],
    )];
    let mut r = rig(&specs);
    r.feed(ev(0, "c1", "m1", 1.0));
    let replies = r.feed(ev(1, "c2", "m2", 1.0));
    assert_eq!(replies[0].value, Some(2.0));
    assert_eq!(replies[0].group, "");
}

#[test]
fn null_fields_are_excluded_from_field_aggs() {
    let specs = vec![
        MetricSpec::new(
            "sum",
            AggKind::Sum,
            Some("amount"),
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "count",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
    ];
    let mut r = rig(&specs);
    r.feed(ev(0, "c1", "m1", 5.0));
    let e = Event::new(
        10,
        vec![Value::Str("c1".into()), Value::Str("m1".into()), Value::Null],
    );
    let replies = r.feed(e);
    let sum = replies.iter().find(|x| x.metric == "sum").unwrap();
    assert_eq!(sum.value, Some(5.0), "null amount not summed");
    let count = replies.iter().find(|x| x.metric == "count").unwrap();
    assert_eq!(count.value, Some(2.0), "count(*) includes the event");
    // ... and the expiry path is symmetric (no double-evict panic)
    let replies = r.feed(ev(2 * ms::MINUTE, "c1", "m1", 1.0));
    let sum = replies.iter().find(|x| x.metric == "sum").unwrap();
    assert_eq!(sum.value, Some(1.0));
}

#[test]
fn advance_batch_equals_per_event_advance() {
    // the same event stream through advance() and advance_batch() must
    // produce identical replies and identical final state — batching is
    // transport-only, never a semantics change
    let mut rng = Rng::new(7);
    let events: Vec<Event> = (0..200)
        .map(|i| {
            ev(
                i * 700 + rng.range_i64(0, 500),
                &format!("c{}", rng.next_below(4)),
                &format!("m{}", rng.next_below(3)),
                rng.next_below(100) as f64,
            )
        })
        .collect();

    let mut single = rig(&q1_specs());
    let mut single_replies = Vec::new();
    for e in &events {
        single_replies.extend(single.feed(e.clone()));
    }

    let mut batched = rig(&q1_specs());
    let mut batched_replies = Vec::new();
    let mut last_t = i64::MIN;
    for chunk in events.chunks(17) {
        let mut t_evals = Vec::with_capacity(chunk.len());
        for e in chunk {
            last_t = (e.timestamp + 1).max(last_t);
            t_evals.push(last_t);
            batched.reservoir.append(e).unwrap();
        }
        let mut sink = CollectingSink::default();
        batched.plan.advance_batch(&t_evals, &mut sink).unwrap();
        for replies in sink.events {
            batched_replies.extend(replies);
        }
    }

    assert_eq!(single_replies, batched_replies);
    for card in ["c0", "c1", "c2", "c3"] {
        let key = [Value::Str(card.into())];
        assert_eq!(
            single.plan.value_for("sum_amount_by_card", &key).unwrap(),
            batched.plan.value_for("sum_amount_by_card", &key).unwrap(),
            "{card}"
        );
    }
}

#[test]
fn advance_batch_rejects_time_regression_mid_batch() {
    let mut r = rig(&q1_specs());
    r.reservoir.append(&ev(1000, "c1", "m1", 1.0)).unwrap();
    let mut sink = CollectingSink::default();
    assert!(r.plan.advance_batch(&[1001, 500], &mut sink).is_err());
    assert_eq!(
        sink.events.len(),
        1,
        "the evaluated prefix's replies survive the error"
    );
    // the store is still usable after the failed batch
    r.reservoir.append(&ev(2000, "c1", "m1", 1.0)).unwrap();
    assert!(r.plan.advance(2001).is_ok());
}

#[test]
fn colliding_group_keys_across_group_nodes_get_distinct_displays() {
    // "a\x1fb" under GROUP BY card produces the same key bytes as
    // ("a", "b") under GROUP BY card, merchant — the 0x1f join is not
    // injective across group nodes. The group-node salt in the intern
    // key must keep the two groups (and their display strings) apart.
    let specs = vec![
        MetricSpec::new(
            "by_card",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card"],
        ),
        MetricSpec::new(
            "by_card_merchant",
            AggKind::Count,
            None,
            WindowSpec::sliding(ms::MINUTE),
            &["card", "merchant"],
        ),
    ];
    let mut r = rig(&specs);
    let first = r.feed(ev(0, "a\u{1f}b", "x", 1.0));
    let second = r.feed(ev(1, "a", "b", 1.0));
    let one = first.iter().find(|x| x.metric == "by_card").unwrap();
    assert_eq!(one.group, "a\u{1f}b");
    let two = second
        .iter()
        .find(|x| x.metric == "by_card_merchant")
        .unwrap();
    assert_eq!(two.group, "a,b", "colliding bytes must not share a display");
    assert_eq!(one.value, Some(1.0));
    assert_eq!(two.value, Some(1.0));
    // four distinct groups were interned: without the salt, the
    // colliding pair collapsed into one entry (and one display)
    assert_eq!(r.plan.interned_groups(), 4);
}

#[test]
fn anomaly_score_streams_through_the_plan() {
    let specs = vec![MetricSpec::new(
        "amount_anomaly",
        AggKind::AnomalyScore,
        Some("amount"),
        WindowSpec::sliding(5 * ms::MINUTE),
        &["card"],
    )
    .with_bands([2.0, 3.0, 4.0])];
    let mut r = rig(&specs);
    for (i, v) in [10.0, 10.4, 9.6, 10.1, 9.9, 10.2].iter().enumerate() {
        let replies = r.feed(ev(i as i64 * 1000, "c1", "m1", *v));
        let z = replies[0].value.unwrap();
        assert!(z.abs() < 2.0, "baseline stays nominal, got {z}");
    }
    let replies = r.feed(ev(7_000, "c1", "m1", 50.0));
    let z = replies[0].value.unwrap();
    assert!(z > 2.0, "outlier amount scores high, got {z}");
    // far in the future the old window has fully expired: a fresh
    // single-observation window has no spread and scores 0
    let replies = r.feed(ev(20 * ms::MINUTE, "c1", "m1", 10.0));
    assert_eq!(replies[0].value, Some(0.0));
}

#[test]
fn checkpoint_positions_roundtrip() {
    let mut r = rig(&q1_specs());
    for i in 0..40 {
        r.feed(ev(i * 1000, "c1", "m1", 1.0));
    }
    let pos = r.plan.positions();
    let t = r.plan.last_t_eval();
    assert_eq!(pos.len(), 2);
    let tail = pos.iter().find(|(o, _)| *o == 0).unwrap();
    assert_eq!(tail.1, 40, "tail iterator consumed all 40 events");
    // restore into a fresh plan over the same reservoir/state
    let store = Arc::new(
        Store::open(&r._tmp.join("state2"), StoreOptions::default()).unwrap(),
    );
    let mut plan2 = Plan::build(
        schema(),
        &q1_specs(),
        &r.reservoir,
        StateStore::new(store, 1000),
    )
    .unwrap();
    plan2.restore_positions(&pos, t);
    assert_eq!(plan2.positions(), pos);
    assert_eq!(plan2.last_t_eval(), t);
}
