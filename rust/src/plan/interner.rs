//! Group-key interning: the zero-allocation half of the per-event
//! group-by path.
//!
//! The pre-interning hot path re-rendered every group on every reply
//! (`Vec<String>` + `join`) and keyed the state cache by freshly-composed
//! `Vec<u8>`s. The interner collapses all of that to **one hash lookup
//! per (event, group node)**: the plan's gather dispatch builds the
//! group's key bytes in a reusable scratch buffer — prefixed with the
//! group-node index as a salt, so colliding byte tuples from different
//! group nodes cannot share an entry — resolves them to a dense
//! [`GroupId`], and everything downstream — state slab indexing, reply
//! routing, display rendering — works with the `u32` id. The interner
//! owns the canonical key bytes (the map keys) and the display string,
//! rendered **once** when a group is first seen, so the steady-state
//! per-event loop allocates nothing.
//!
//! Ids are assigned densely in first-seen order. By default they are not
//! persisted: recovery replays the reservoir through the same dispatch
//! path, which re-interns every live group deterministically (and
//! re-renders its display from the replayed events). With checkpointing
//! enabled ([`crate::checkpoint`]), [`GroupInterner::export`] captures
//! the `(key, display)` entries in id order and
//! [`GroupInterner::restore`] re-interns them in that order — restoring
//! the exact id assignment, so slab indices and reply display strings
//! come back bit-identical without a replay.

use crate::util::hash::FxHashMap;

/// Dense id of an interned group key within one [`crate::plan::Plan`].
///
/// Assigned contiguously from 0 in first-seen order — suitable for
/// direct `Vec` indexing (the state slab, per-group side tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupId(pub u32);

/// One interned group: canonical key bytes → dense id + display string.
pub struct GroupInterner {
    /// Canonical key bytes → id. Lookup hashes the scratch key once;
    /// the boxed key is allocated only when a new group is interned.
    ids: FxHashMap<Box<[u8]>, u32>,
    /// id → rendered display (group-by field values joined with `,`).
    displays: Vec<String>,
}

impl GroupInterner {
    /// Empty interner.
    pub fn new() -> GroupInterner {
        GroupInterner {
            ids: FxHashMap::default(),
            displays: Vec::new(),
        }
    }

    /// Resolve `key` to its dense id, interning it when first seen.
    /// `render` produces the display string and runs **only** for a new
    /// group — the steady-state path is one hash + map probe, no
    /// allocation, no rendering.
    #[inline]
    pub fn intern(&mut self, key: &[u8], render: impl FnOnce() -> String) -> GroupId {
        if let Some(&id) = self.ids.get(key) {
            return GroupId(id);
        }
        let id = self.displays.len() as u32;
        self.ids.insert(key.into(), id);
        self.displays.push(render());
        GroupId(id)
    }

    /// Non-interning lookup (query/inspection paths).
    pub fn lookup(&self, key: &[u8]) -> Option<GroupId> {
        self.ids.get(key).map(|&id| GroupId(id))
    }

    /// Display string of an interned group.
    #[inline]
    pub fn display(&self, id: GroupId) -> &str {
        &self.displays[id.0 as usize]
    }

    /// Number of interned groups.
    pub fn len(&self) -> usize {
        self.displays.len()
    }

    /// True when no group has been interned yet.
    pub fn is_empty(&self) -> bool {
        self.displays.is_empty()
    }

    /// Every interned entry as `(canonical key bytes, display string)`
    /// in dense id order — the checkpoint image of the interner.
    pub fn export(&self) -> Vec<(Vec<u8>, String)> {
        let mut out: Vec<(Vec<u8>, String)> = vec![Default::default(); self.displays.len()];
        for (key, &id) in &self.ids {
            out[id as usize] = (key.to_vec(), self.displays[id as usize].clone());
        }
        out
    }

    /// Rebuild from an [`export`](Self::export) image: entries are
    /// interned in order, reproducing the original id assignment.
    /// Errors if the interner is not empty (restore is a recovery-time
    /// operation, before any event is dispatched).
    pub fn restore(&mut self, entries: &[(Vec<u8>, String)]) -> crate::error::Result<()> {
        if !self.is_empty() {
            return Err(crate::error::Error::invalid(
                "interner restore requires an empty interner",
            ));
        }
        for (i, (key, display)) in entries.iter().enumerate() {
            let id = self.intern(key, || display.clone());
            if id.0 as usize != i {
                return Err(crate::error::Error::corrupt(
                    "interner restore: duplicate key in snapshot",
                ));
            }
        }
        Ok(())
    }
}

impl Default for GroupInterner {
    fn default() -> Self {
        GroupInterner::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interns_densely_in_first_seen_order() {
        let mut i = GroupInterner::new();
        assert!(i.is_empty());
        let a = i.intern(b"c1\x1f", || "c1".to_string());
        let b = i.intern(b"c2\x1f", || "c2".to_string());
        assert_eq!(a, GroupId(0));
        assert_eq!(b, GroupId(1));
        assert_eq!(i.len(), 2);
        assert_eq!(i.display(a), "c1");
        assert_eq!(i.display(b), "c2");
    }

    #[test]
    fn repeat_intern_reuses_id_and_never_rerenders() {
        let mut i = GroupInterner::new();
        let a = i.intern(b"k", || "k".to_string());
        let again = i.intern(b"k", || panic!("render must not run for a known group"));
        assert_eq!(a, again);
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn lookup_does_not_intern() {
        let mut i = GroupInterner::new();
        assert_eq!(i.lookup(b"x"), None);
        let id = i.intern(b"x", || "x".to_string());
        assert_eq!(i.lookup(b"x"), Some(id));
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn export_restore_reproduces_ids_and_displays() {
        let mut i = GroupInterner::new();
        i.intern(b"c1\x1f", || "c1".to_string());
        i.intern(b"c2\x1f", || "c2".to_string());
        i.intern(b"", || String::new());
        let image = i.export();
        assert_eq!(image.len(), 3);
        assert_eq!(image[1], (b"c2\x1f".to_vec(), "c2".to_string()));

        let mut j = GroupInterner::new();
        j.restore(&image).unwrap();
        assert_eq!(j.len(), 3);
        assert_eq!(j.lookup(b"c2\x1f"), Some(GroupId(1)));
        assert_eq!(j.display(GroupId(0)), "c1");
        assert_eq!(j.display(GroupId(2)), "");
        // restore refuses a non-empty interner
        assert!(j.restore(&image).is_err());
        // a duplicate key in a (corrupt) image is rejected
        let mut dup = image.clone();
        dup.push(image[0].clone());
        let mut k = GroupInterner::new();
        assert!(k.restore(&dup).is_err());
    }

    #[test]
    fn empty_key_is_a_valid_group() {
        // global aggregates (no group-by) intern the empty key
        let mut i = GroupInterner::new();
        let id = i.intern(b"", || String::new());
        assert_eq!(i.display(id), "");
        assert_eq!(i.intern(b"", || unreachable!()), id);
    }
}
