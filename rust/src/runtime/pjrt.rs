//! Thin wrapper over the `xla` crate's PJRT client.

use crate::error::{Error, Result};
use std::path::Path;

/// A PJRT client (CPU). One per process; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (observability).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (once; execution is
    /// cheap thereafter).
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {path:?}: {e}")))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled artifact, executable from the hot path.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the untupled outputs.
    ///
    /// The compile path lowers with `return_tuple=True`, so the PJRT
    /// result is a single tuple literal which we decompose.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::runtime(format!("{}: execute: {e}", self.name)))?;
        let buffer = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::runtime(format!("{}: empty result", self.name)))?;
        let tuple = buffer
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("{}: to_literal: {e}", self.name)))?;
        tuple
            .to_tuple()
            .map_err(|e| Error::runtime(format!("{}: untuple: {e}", self.name)))
    }

    /// Artifact name (file stem).
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Build an f32 literal of the given dimensions.
pub(crate) fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::runtime(format!(
            "literal shape {dims:?} wants {n} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::runtime(format!("reshape: {e}")))
}

/// Build an i32 literal of the given dimensions.
pub(crate) fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::runtime(format!(
            "literal shape {dims:?} wants {n} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| Error::runtime(format!("reshape: {e}")))
}
