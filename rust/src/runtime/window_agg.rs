//! Vectorized aggregation backend: the AOT `window_agg` artifact.
//!
//! An alternative to the scalar per-event state updates in
//! [`crate::plan`]: arrive/expire deltas are accumulated into fixed-size
//! batches and applied to a slot-indexed state matrix in one XLA call
//! (the L1 one-hot-matmul kernel). The ablation bench compares this
//! against the scalar path; on real TPU hardware the batched path is the
//! one that scales (DESIGN.md §5).

use crate::error::{Error, Result};
use crate::runtime::pjrt::{literal_f32, literal_i32, Executable, Runtime};
use crate::util::json::Json;
use std::path::Path;

/// Shape contract of the window_agg artifact.
#[derive(Debug, Clone, Copy)]
pub struct AggMeta {
    /// Slot count (state rows).
    pub slots: usize,
    /// Delta batch size.
    pub batch: usize,
    /// State lanes (`[count, sum, sumsq, pad…]`).
    pub lanes: usize,
}

/// Host-resident state matrix + the compiled update executable.
pub struct VectorizedAgg {
    exe: Executable,
    meta: AggMeta,
    state: Vec<f32>,
    // pending delta batch
    slots: Vec<i32>,
    values: Vec<f32>,
    signs: Vec<f32>,
    /// XLA executions performed (bench observability).
    pub flushes: u64,
}

impl VectorizedAgg {
    /// Load + compile the artifact from `dir`.
    pub fn load(runtime: &Runtime, dir: &Path) -> Result<VectorizedAgg> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))?;
        let meta_json = Json::parse(&meta_text)?;
        let agg = meta_json
            .get("window_agg")
            .ok_or_else(|| Error::runtime("meta.json: missing window_agg"))?;
        let get = |k: &str| -> Result<usize> {
            agg.get(k)
                .and_then(|j| j.as_i64())
                .map(|v| v as usize)
                .ok_or_else(|| Error::runtime(format!("meta.json: missing {k}")))
        };
        let meta = AggMeta {
            slots: get("slots")?,
            batch: get("batch")?,
            lanes: get("lanes")?,
        };
        let exe = runtime.load_hlo_text(&dir.join("window_agg.hlo.txt"))?;
        Ok(VectorizedAgg {
            exe,
            meta,
            state: vec![0.0; meta.slots * meta.lanes],
            slots: Vec::with_capacity(meta.batch),
            values: Vec::with_capacity(meta.batch),
            signs: Vec::with_capacity(meta.batch),
            flushes: 0,
        })
    }

    /// Shape contract.
    pub fn meta(&self) -> AggMeta {
        self.meta
    }

    /// Queue one delta; flushes automatically when the batch fills.
    pub fn push(&mut self, slot: u32, value: f32, arrive: bool) -> Result<()> {
        if slot as usize >= self.meta.slots {
            return Err(Error::runtime(format!(
                "slot {slot} out of range ({})",
                self.meta.slots
            )));
        }
        self.slots.push(slot as i32);
        self.values.push(value);
        self.signs.push(if arrive { 1.0 } else { -1.0 });
        if self.slots.len() == self.meta.batch {
            self.flush()?;
        }
        Ok(())
    }

    /// Apply all queued deltas (pads the batch with sign-0 rows).
    pub fn flush(&mut self) -> Result<()> {
        if self.slots.is_empty() {
            return Ok(());
        }
        let b = self.meta.batch;
        self.slots.resize(b, 0);
        self.values.resize(b, 0.0);
        self.signs.resize(b, 0.0); // sign 0 ⇒ no-op rows
        let state = literal_f32(
            &self.state,
            &[self.meta.slots as i64, self.meta.lanes as i64],
        )?;
        let slots = literal_i32(&self.slots, &[b as i64])?;
        let values = literal_f32(&self.values, &[b as i64])?;
        let signs = literal_f32(&self.signs, &[b as i64])?;
        let outputs = self.exe.run(&[state, slots, values, signs])?;
        self.state = outputs
            .first()
            .ok_or_else(|| Error::runtime("window_agg: no output"))?
            .to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("window_agg output: {e}")))?;
        self.slots.clear();
        self.values.clear();
        self.signs.clear();
        self.flushes += 1;
        Ok(())
    }

    /// `[count, sum, sumsq]` for a slot (flushes pending deltas first).
    pub fn lanes(&mut self, slot: u32) -> Result<(f64, f64, f64)> {
        self.flush()?;
        let base = slot as usize * self.meta.lanes;
        let row = &self.state[base..base + 3];
        Ok((row[0] as f64, row[1] as f64, row[2] as f64))
    }

    /// Derived aggregates for a slot: (count, sum, avg, stddev).
    pub fn aggregates(&mut self, slot: u32) -> Result<(f64, f64, Option<f64>, Option<f64>)> {
        let (count, sum, sumsq) = self.lanes(slot)?;
        if count <= 0.0 {
            return Ok((0.0, 0.0, None, None));
        }
        let mean = sum / count;
        let var = (sumsq / count - mean * mean).max(0.0);
        Ok((count, sum, Some(mean), Some(var.sqrt())))
    }
}
