//! Runtime: load and execute the AOT artifacts via PJRT (`xla` crate).
//!
//! The compile path (python, build-time only — see `python/compile/`)
//! lowers the L2 JAX graphs to HLO **text**; this module parses the text
//! (`HloModuleProto::from_text_file`, which reassigns instruction ids and
//! sidesteps the jax≥0.5 64-bit-id proto incompatibility), compiles each
//! module once on the PJRT CPU client, and executes from the rust hot
//! path. Python never runs at request time.
//!
//! The PJRT layer is gated behind the non-default `pjrt` cargo feature
//! (it needs the `xla` crate, which is not in the offline crate set);
//! the default build is pure Rust and only exposes the artifact-path
//! helpers below.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
mod scorer;
#[cfg(feature = "pjrt")]
mod window_agg;

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use scorer::{FraudScorer, ScorerBatcher, ScorerMeta};
#[cfg(feature = "pjrt")]
pub use window_agg::{AggMeta, VectorizedAgg};

use std::path::PathBuf;

/// Resolve the artifacts directory: `RAILGUN_ARTIFACTS` env override, else
/// `<repo>/artifacts` (CARGO_MANIFEST_DIR at build time, cwd fallback).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("RAILGUN_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

/// True when `make artifacts` has produced the AOT outputs.
pub fn artifacts_available() -> bool {
    let dir = artifacts_dir();
    dir.join("window_agg.hlo.txt").exists()
        && dir.join("fraud_scorer.hlo.txt").exists()
        && dir.join("meta.json").exists()
}
