//! The fraud scorer: AOT-compiled MLP served from the rust hot path.
//!
//! Scoring is **micro-batched**: the artifact has a fixed batch shape
//! (`meta.json`), so callers accumulate feature rows and flush when the
//! batch fills (or on an explicit deadline in the serving loop). Partial
//! batches pad by repeating the last row — pure overhead, no semantic
//! effect, exactly what the paper-scale serving path would do.

use crate::error::{Error, Result};
use crate::runtime::pjrt::{literal_f32, Executable, Runtime};
use crate::util::json::Json;
use std::path::Path;

/// Shape contract of the scorer artifact (from `meta.json`).
#[derive(Debug, Clone)]
pub struct ScorerMeta {
    /// Fixed batch size.
    pub batch: usize,
    /// Feature count per row.
    pub features: usize,
    /// Feature names, in row order (`python/compile/model.py`).
    pub feature_names: Vec<String>,
}

/// AOT fraud scorer.
pub struct FraudScorer {
    exe: Executable,
    meta: ScorerMeta,
}

impl FraudScorer {
    /// Load + compile the scorer artifact from `dir`.
    pub fn load(runtime: &Runtime, dir: &Path) -> Result<FraudScorer> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))?;
        let meta_json = Json::parse(&meta_text)?;
        let scorer = meta_json
            .get("fraud_scorer")
            .ok_or_else(|| Error::runtime("meta.json: missing fraud_scorer"))?;
        let get = |k: &str| -> Result<i64> {
            scorer
                .get(k)
                .and_then(|j| j.as_i64())
                .ok_or_else(|| Error::runtime(format!("meta.json: missing {k}")))
        };
        let meta = ScorerMeta {
            batch: get("batch")? as usize,
            features: get("features")? as usize,
            feature_names: scorer
                .get("feature_names")
                .and_then(|j| j.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|j| j.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
        };
        let exe = runtime.load_hlo_text(&dir.join("fraud_scorer.hlo.txt"))?;
        Ok(FraudScorer { exe, meta })
    }

    /// Shape contract.
    pub fn meta(&self) -> &ScorerMeta {
        &self.meta
    }

    /// Score `n_rows` feature rows (flattened row-major). Rows beyond the
    /// batch capacity are rejected; partial batches are padded.
    pub fn score(&self, rows_flat: &[f32], n_rows: usize) -> Result<Vec<f32>> {
        let (b, f) = (self.meta.batch, self.meta.features);
        if n_rows == 0 {
            return Ok(Vec::new());
        }
        if n_rows > b {
            return Err(Error::runtime(format!(
                "scorer batch overflow: {n_rows} > {b}"
            )));
        }
        if rows_flat.len() != n_rows * f {
            return Err(Error::runtime(format!(
                "expected {n_rows}×{f} features, got {}",
                rows_flat.len()
            )));
        }
        let mut padded = Vec::with_capacity(b * f);
        padded.extend_from_slice(rows_flat);
        let last_row = &rows_flat[(n_rows - 1) * f..];
        for _ in n_rows..b {
            padded.extend_from_slice(last_row);
        }
        let input = literal_f32(&padded, &[b as i64, f as i64])?;
        let outputs = self.exe.run(&[input])?;
        let probs: Vec<f32> = outputs
            .first()
            .ok_or_else(|| Error::runtime("scorer: no output"))?
            .to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("scorer output: {e}")))?;
        Ok(probs[..n_rows].to_vec())
    }
}

/// Accumulates feature rows and flushes fixed-size batches.
pub struct ScorerBatcher<'a> {
    scorer: &'a FraudScorer,
    buf: Vec<f32>,
    rows: usize,
}

impl<'a> ScorerBatcher<'a> {
    /// New batcher over a scorer.
    pub fn new(scorer: &'a FraudScorer) -> Self {
        let cap = scorer.meta.batch * scorer.meta.features;
        ScorerBatcher {
            scorer,
            buf: Vec::with_capacity(cap),
            rows: 0,
        }
    }

    /// Push one feature row; returns scores when the batch filled.
    pub fn push(&mut self, row: &[f32]) -> Result<Option<Vec<f32>>> {
        if row.len() != self.scorer.meta.features {
            return Err(Error::runtime(format!(
                "row has {} features, scorer wants {}",
                row.len(),
                self.scorer.meta.features
            )));
        }
        self.buf.extend_from_slice(row);
        self.rows += 1;
        if self.rows == self.scorer.meta.batch {
            return Ok(Some(self.flush()?));
        }
        Ok(None)
    }

    /// Flush whatever is buffered (possibly a partial batch).
    pub fn flush(&mut self) -> Result<Vec<f32>> {
        let scores = self.scorer.score(&self.buf, self.rows)?;
        self.buf.clear();
        self.rows = 0;
        Ok(scores)
    }

    /// Buffered (unflushed) rows.
    pub fn pending(&self) -> usize {
        self.rows
    }
}
