//! Flink-style hopping-window engine.

use crate::agg::{AggKind, AggState};
use crate::error::{Error, Result};
use crate::event::{Event, SchemaRef, Value};
use crate::kvstore::Store;
use crate::util::hash::{self, FxHashMap};
use crate::window::panes;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Hopping engine configuration (one metric, as in the paper's §4.2
/// experiment: `sum(amount) group by card` over a 60-min window).
#[derive(Debug, Clone)]
pub struct HoppingConfig {
    /// Window size (ms).
    pub size_ms: i64,
    /// Hop (ms).
    pub hop_ms: i64,
    /// Aggregation (additive only — hopping panes cannot evict; Min/Max
    /// are fine because panes are add-only and die whole).
    pub agg: AggKind,
    /// Aggregated field.
    pub field: Option<String>,
    /// Group-by fields.
    pub group_by: Vec<String>,
    /// Persist pane states to the kvstore on every update (Flink+RocksDB
    /// behaviour). Disable to measure the pure in-memory cost.
    pub persist: bool,
}

/// A fired pane result.
#[derive(Debug, Clone, PartialEq)]
pub struct PaneResult {
    /// Pane start (ms).
    pub start: i64,
    /// Fire time = start + size.
    pub fire_time: i64,
    /// Rendered group key.
    pub group: String,
    /// Aggregate over the pane.
    pub value: Option<f64>,
}

struct PaneStates {
    /// key-bytes → (display, state)
    by_key: FxHashMap<Vec<u8>, (String, AggState)>,
}

/// The Type-2 baseline engine.
pub struct HoppingEngine {
    cfg: HoppingConfig,
    schema: SchemaRef,
    field_idx: Option<usize>,
    group_idxs: Vec<usize>,
    /// pane start → per-key states. BTreeMap so firing pops the oldest.
    panes: BTreeMap<i64, PaneStates>,
    store: Option<Arc<Store>>,
    /// Highest event time seen (the watermark driving pane firing).
    watermark: i64,
    /// Most recent fired value per key (what a downstream rule "sees").
    last_fired: FxHashMap<Vec<u8>, PaneResult>,
    /// Counters: pane-state updates and store writes (the §2.2 cost
    /// accounting).
    pub pane_updates: u64,
    /// kvstore writes performed.
    pub store_writes: u64,
    /// Panes fired.
    pub panes_fired: u64,
    scratch: Vec<u8>,
}

impl HoppingEngine {
    /// Build the engine. `store` mirrors Flink's RocksDB state backend.
    pub fn new(
        cfg: HoppingConfig,
        schema: SchemaRef,
        store: Option<Arc<Store>>,
    ) -> Result<HoppingEngine> {
        if cfg.size_ms <= 0 || cfg.hop_ms <= 0 || cfg.hop_ms > cfg.size_ms {
            return Err(Error::invalid("hopping: need 0 < hop ≤ size"));
        }
        if cfg.agg.needs_field() && cfg.field.is_none() {
            return Err(Error::invalid("hopping: aggregation needs a field"));
        }
        let field_idx = match &cfg.field {
            Some(f) => Some(
                schema
                    .index_of(f)
                    .ok_or_else(|| Error::invalid(format!("unknown field '{f}'")))?,
            ),
            None => None,
        };
        let group_idxs = cfg
            .group_by
            .iter()
            .map(|g| {
                schema
                    .index_of(g)
                    .ok_or_else(|| Error::invalid(format!("unknown group-by '{g}'")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(HoppingEngine {
            cfg,
            schema,
            field_idx,
            group_idxs,
            panes: BTreeMap::new(),
            store,
            watermark: i64::MIN,
            last_fired: FxHashMap::default(),
            pane_updates: 0,
            store_writes: 0,
            panes_fired: 0,
            scratch: Vec::with_capacity(64),
        })
    }

    /// Number of live panes (observability — `windowSize/hopSize` once
    /// warm).
    pub fn live_panes(&self) -> usize {
        self.panes.len()
    }

    /// Process one event; returns panes fired by the watermark advance.
    pub fn on_event(&mut self, event: &Event) -> Result<Vec<PaneResult>> {
        let _ = &self.schema;
        let ts = event.timestamp;
        // 1. update every pane containing ts (the Θ(size/hop) fan-out)
        let (val, raw_hash, include) = match self.field_idx {
            None => (0.0, 0u64, true),
            Some(fi) => match event.value(fi) {
                Value::Null => (0.0, 0, false),
                v => {
                    if self.cfg.agg == AggKind::CountDistinct {
                        let mut kb = Vec::with_capacity(16);
                        v.key_bytes(&mut kb);
                        (0.0, hash::hash64(&kb), true)
                    } else {
                        match v.as_f64() {
                            Some(x) => (x, 0, true),
                            None => (0.0, 0, false),
                        }
                    }
                }
            },
        };
        if include {
            self.scratch.clear();
            for &gi in &self.group_idxs {
                event.value(gi).key_bytes(&mut self.scratch);
                self.scratch.push(0x1f);
            }
            let display = self
                .group_idxs
                .iter()
                .map(|&i| event.value(i).to_string())
                .collect::<Vec<_>>()
                .join(",");
            for start in panes::pane_starts(ts, self.cfg.size_ms, self.cfg.hop_ms) {
                let pane = self.panes.entry(start).or_insert_with(|| PaneStates {
                    by_key: FxHashMap::default(),
                });
                let agg = self.cfg.agg;
                let (_, state) = pane
                    .by_key
                    .entry(self.scratch.clone())
                    .or_insert_with(|| (display.clone(), AggState::new(agg)));
                state.add(0, val, raw_hash);
                self.pane_updates += 1;
                if self.cfg.persist {
                    if let Some(store) = &self.store {
                        // key: pane start ++ group key
                        let mut k = Vec::with_capacity(self.scratch.len() + 9);
                        k.extend_from_slice(&start.to_be_bytes());
                        k.extend_from_slice(&self.scratch);
                        let mut v = Vec::with_capacity(32);
                        state.encode(&mut v);
                        store.put(&k, &v)?;
                        self.store_writes += 1;
                    }
                }
            }
        }
        // 2. advance the watermark; fire panes whose end has passed
        self.watermark = self.watermark.max(ts);
        self.fire_up_to(self.watermark)
    }

    /// Fire every pane with `fire_time ≤ watermark` (Flink emits window
    /// results when the window closes).
    pub fn fire_up_to(&mut self, watermark: i64) -> Result<Vec<PaneResult>> {
        let mut fired = Vec::new();
        loop {
            let start = match self.panes.keys().next() {
                Some(&s) if panes::fire_time(s, self.cfg.size_ms) <= watermark => s,
                _ => break,
            };
            let pane = self.panes.remove(&start).expect("checked above");
            let fire_time = panes::fire_time(start, self.cfg.size_ms);
            for (key, (display, state)) in pane.by_key {
                let result = PaneResult {
                    start,
                    fire_time,
                    group: display,
                    value: state.value(),
                };
                self.last_fired.insert(key.clone(), result.clone());
                if self.cfg.persist {
                    if let Some(store) = &self.store {
                        let mut k = Vec::with_capacity(key.len() + 9);
                        k.extend_from_slice(&start.to_be_bytes());
                        k.extend_from_slice(&key);
                        store.delete(&k)?;
                        self.store_writes += 1;
                    }
                }
                self.panes_fired += 1;
                fired.push(result);
            }
        }
        Ok(fired)
    }

    /// The value a downstream rule sees for `group_values` right now: the
    /// most recently fired pane's aggregate (hopping windows only publish
    /// at hop boundaries — the accuracy gap of Figure 1).
    pub fn visible_value(&mut self, group_values: &[Value]) -> Option<&PaneResult> {
        self.scratch.clear();
        let mut key = Vec::with_capacity(32);
        for v in group_values {
            v.key_bytes(&mut key);
            key.push(0x1f);
        }
        self.last_fired.get(&key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{FieldType, Schema};
    use crate::util::clock::ms;

    fn schema() -> SchemaRef {
        Schema::of(&[("card", FieldType::Str), ("amount", FieldType::F64)]).unwrap()
    }

    fn ev(ts: i64, card: &str, amount: f64) -> Event {
        Event::new(ts, vec![Value::Str(card.into()), Value::F64(amount)])
    }

    fn engine(size: i64, hop: i64) -> HoppingEngine {
        HoppingEngine::new(
            HoppingConfig {
                size_ms: size,
                hop_ms: hop,
                agg: AggKind::Sum,
                field: Some("amount".into()),
                group_by: vec!["card".into()],
                persist: false,
            },
            schema(),
            None,
        )
        .unwrap()
    }

    #[test]
    fn pane_fanout_is_size_over_hop() {
        let mut e = engine(5 * ms::MINUTE, ms::MINUTE);
        e.on_event(&ev(10 * ms::MINUTE, "c1", 1.0)).unwrap();
        assert_eq!(e.pane_updates, 5, "one update per overlapping pane");
        assert_eq!(e.live_panes(), 5);
    }

    #[test]
    fn tumbling_single_pane() {
        let mut e = engine(ms::MINUTE, ms::MINUTE);
        e.on_event(&ev(30_000, "c1", 1.0)).unwrap();
        assert_eq!(e.pane_updates, 1);
    }

    #[test]
    fn panes_fire_when_watermark_passes() {
        let mut e = engine(2 * ms::MINUTE, ms::MINUTE);
        e.on_event(&ev(0, "c1", 10.0)).unwrap();
        e.on_event(&ev(30_000, "c1", 5.0)).unwrap();
        // pane [-1min, 1min) fires when watermark ≥ 1min
        let fired = e.on_event(&ev(ms::MINUTE + 1, "c1", 1.0)).unwrap();
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].start, -ms::MINUTE);
        assert_eq!(fired[0].value, Some(15.0), "both early events in pane");
        // rule now "sees" 15 for c1
        let seen = e.visible_value(&[Value::Str("c1".into())]).unwrap();
        assert_eq!(seen.value, Some(15.0));
    }

    #[test]
    fn fired_values_match_pane_contents_per_key() {
        let mut e = engine(2 * ms::MINUTE, ms::MINUTE);
        e.on_event(&ev(0, "a", 1.0)).unwrap();
        e.on_event(&ev(1, "b", 2.0)).unwrap();
        let fired = e.fire_up_to(10 * ms::MINUTE).unwrap();
        // two panes contain the events ([-1m,1m) and [0,2m)) × 2 keys
        assert_eq!(fired.len(), 4);
        let a_total: f64 = fired
            .iter()
            .filter(|r| r.group == "a")
            .map(|r| r.value.unwrap())
            .sum();
        assert_eq!(a_total, 2.0, "key a appears in 2 panes with value 1.0");
    }

    #[test]
    fn figure1_hopping_never_sees_five() {
        // the paper's Figure 1: 5 events in a true 5-min span, 1-min hop
        let m = ms::MINUTE;
        let mut e = HoppingEngine::new(
            HoppingConfig {
                size_ms: 5 * m,
                hop_ms: m,
                agg: AggKind::Count,
                field: None,
                group_by: vec!["card".into()],
                persist: false,
            },
            schema(),
            None,
        )
        .unwrap();
        let times = [30_000, m + 30_000, 2 * m + 30_000, 3 * m + 30_000, 5 * m + 15_000];
        let mut fired_all = Vec::new();
        for t in times {
            fired_all.extend(e.on_event(&ev(t, "c1", 1.0)).unwrap());
        }
        fired_all.extend(e.fire_up_to(i64::MAX).unwrap());
        let max_count = fired_all
            .iter()
            .filter_map(|r| r.value)
            .fold(0.0f64, f64::max);
        assert!(
            max_count < 5.0,
            "no pane captures all 5 events (max={max_count})"
        );
    }

    #[test]
    fn persistence_writes_to_store() {
        let tmp = crate::util::tmp::TempDir::new("hopping_store");
        let store = Arc::new(
            Store::open(tmp.path(), crate::kvstore::StoreOptions::default()).unwrap(),
        );
        let mut e = HoppingEngine::new(
            HoppingConfig {
                size_ms: 5 * ms::MINUTE,
                hop_ms: ms::MINUTE,
                agg: AggKind::Sum,
                field: Some("amount".into()),
                group_by: vec!["card".into()],
                persist: true,
            },
            schema(),
            Some(store),
        )
        .unwrap();
        e.on_event(&ev(0, "c1", 5.0)).unwrap();
        assert_eq!(e.store_writes, 5, "one store write per pane update");
    }

    #[test]
    fn config_validation() {
        let bad = |size, hop| {
            HoppingEngine::new(
                HoppingConfig {
                    size_ms: size,
                    hop_ms: hop,
                    agg: AggKind::Count,
                    field: None,
                    group_by: vec![],
                    persist: false,
                },
                schema(),
                None,
            )
            .is_err()
        };
        assert!(bad(0, 1));
        assert!(bad(1000, 0));
        assert!(bad(1000, 2000));
    }
}
