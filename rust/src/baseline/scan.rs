//! The Flink-blog "custom window" baseline (paper §2.2, cite [13]):
//! accurate sliding values obtained by storing every event per key and
//! recomputing the aggregate from scratch on each arrival. Quadratic in
//! window occupancy — the pattern the paper says "fails requirement L".

use crate::agg::{AggKind, AggState};
use crate::error::{Error, Result};
use crate::event::{Event, SchemaRef, Value};
use crate::util::hash::{self, FxHashMap};
use std::collections::VecDeque;

/// Per-key stored events: (ts, value, raw_hash).
type KeyLog = VecDeque<(i64, f64, u64)>;

/// Scan-recompute sliding baseline.
pub struct ScanSlidingEngine {
    size_ms: i64,
    agg: AggKind,
    field_idx: Option<usize>,
    group_idxs: Vec<usize>,
    events: FxHashMap<Vec<u8>, KeyLog>,
    /// Events visited by recomputation scans (the quadratic term).
    pub scanned: u64,
    scratch: Vec<u8>,
}

impl ScanSlidingEngine {
    /// Build for one metric.
    pub fn new(
        size_ms: i64,
        agg: AggKind,
        field: Option<&str>,
        group_by: &[&str],
        schema: &SchemaRef,
    ) -> Result<ScanSlidingEngine> {
        if size_ms <= 0 {
            return Err(Error::invalid("scan baseline: size must be positive"));
        }
        let field_idx = match field {
            Some(f) => Some(
                schema
                    .index_of(f)
                    .ok_or_else(|| Error::invalid(format!("unknown field '{f}'")))?,
            ),
            None => None,
        };
        let group_idxs = group_by
            .iter()
            .map(|g| {
                schema
                    .index_of(g)
                    .ok_or_else(|| Error::invalid(format!("unknown group-by '{g}'")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ScanSlidingEngine {
            size_ms,
            agg,
            field_idx,
            group_idxs,
            events: FxHashMap::default(),
            scanned: 0,
            scratch: Vec::with_capacity(64),
        })
    }

    /// Process one event; returns the accurate aggregate for its group
    /// (recomputed by scanning all stored in-window events).
    pub fn on_event(&mut self, event: &Event) -> Result<Option<f64>> {
        let ts = event.timestamp;
        self.scratch.clear();
        for &gi in &self.group_idxs {
            event.value(gi).key_bytes(&mut self.scratch);
            self.scratch.push(0x1f);
        }
        let (val, raw_hash, include) = match self.field_idx {
            None => (0.0, 0u64, true),
            Some(fi) => match event.value(fi) {
                Value::Null => (0.0, 0, false),
                v => {
                    if self.agg == AggKind::CountDistinct {
                        let mut kb = Vec::with_capacity(16);
                        v.key_bytes(&mut kb);
                        (0.0, hash::hash64(&kb), true)
                    } else {
                        match v.as_f64() {
                            Some(x) => (x, 0, true),
                            None => (0.0, 0, false),
                        }
                    }
                }
            },
        };
        let log = self.events.entry(self.scratch.clone()).or_default();
        if include {
            log.push_back((ts, val, raw_hash));
        }
        // trim expired events (cheap) ...
        let lo = ts + 1 - self.size_ms;
        while let Some(&(t, _, _)) = log.front() {
            if t < lo {
                log.pop_front();
            } else {
                break;
            }
        }
        // ... then recompute from scratch (the quadratic part)
        let mut state = AggState::new(self.agg);
        for (i, &(_, v, h)) in log.iter().enumerate() {
            state.add(i as u64, v, h);
            self.scanned += 1;
        }
        Ok(state.value())
    }

    /// Total events currently stored.
    pub fn stored_events(&self) -> usize {
        self.events.values().map(|l| l.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{FieldType, Schema};
    use crate::util::clock::ms;

    fn schema() -> SchemaRef {
        Schema::of(&[("card", FieldType::Str), ("amount", FieldType::F64)]).unwrap()
    }

    fn ev(ts: i64, card: &str, amount: f64) -> Event {
        Event::new(ts, vec![Value::Str(card.into()), Value::F64(amount)])
    }

    #[test]
    fn values_are_accurate_sliding() {
        let s = schema();
        let mut e =
            ScanSlidingEngine::new(5 * ms::MINUTE, AggKind::Sum, Some("amount"), &["card"], &s)
                .unwrap();
        assert_eq!(e.on_event(&ev(0, "c1", 10.0)).unwrap(), Some(10.0));
        assert_eq!(e.on_event(&ev(ms::MINUTE, "c1", 20.0)).unwrap(), Some(30.0));
        // t=0 expires at 5min
        assert_eq!(
            e.on_event(&ev(5 * ms::MINUTE, "c1", 1.0)).unwrap(),
            Some(21.0)
        );
    }

    #[test]
    fn cost_is_quadratic_in_window_occupancy() {
        let s = schema();
        let mut e =
            ScanSlidingEngine::new(ms::HOUR, AggKind::Sum, Some("amount"), &["card"], &s).unwrap();
        for i in 0..100 {
            e.on_event(&ev(i, "c1", 1.0)).unwrap();
        }
        // sum over scans of growing windows: 1+2+..+100 = 5050
        assert_eq!(e.scanned, 5050);
        assert_eq!(e.stored_events(), 100);
    }

    #[test]
    fn groups_are_independent() {
        let s = schema();
        let mut e =
            ScanSlidingEngine::new(ms::MINUTE, AggKind::Count, None, &["card"], &s).unwrap();
        e.on_event(&ev(0, "a", 1.0)).unwrap();
        let b = e.on_event(&ev(1, "b", 1.0)).unwrap();
        assert_eq!(b, Some(1.0));
    }
}
