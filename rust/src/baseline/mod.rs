//! Baseline engines (the paper's comparison targets, §2.2/§4.2).
//!
//! * [`HoppingEngine`] — the Type-2 (Flink-style) hopping-window
//!   implementation: a fixed set of `windowSize/hopSize` overlapping pane
//!   states per key, updated on arrival and discarded at fire time, with
//!   pane states write-through persisted to the kvstore (Flink keeps them
//!   in RocksDB). Events are discarded once applied — no reservoir. Its
//!   per-event cost is `Θ(size/hop)` state updates, which is exactly the
//!   blow-up Figure 5 measures as the hop shrinks.
//! * [`ScanSlidingEngine`] — the Flink-blog "custom window" pattern the
//!   paper cites ([13]): store every event per key, recompute the
//!   aggregate from scratch per arrival by scanning the stored window —
//!   accurate but quadratic.

mod hopping;
mod scan;

pub use hopping::{HoppingConfig, HoppingEngine, PaneResult};
pub use scan::ScanSlidingEngine;
