//! Front-end layer (paper §3.2): client entry point, event routing and
//! reply collection.
//!
//! On ingest, an event is **replicated to one topic per routing entity**
//! of its stream, partitioned by the hash of that entity's value — this
//! is what guarantees the processing unit computing a metric sees *every*
//! event of its group (accuracy requirement A). The front-end also owns
//! the reply topic: back-end task processors publish their metric values
//! there, and [`ReplyCollector`] reassembles the per-event answer for the
//! client (steps 5–6 of Figure 2).

use crate::config::StreamDef;
use crate::error::{Error, Result};
use crate::event::{codec, Event};
use crate::mlog::{BrokerRef, Consumer, Producer};
use crate::util::hash::FxHashMap;
use crate::util::json::Json;
use crate::util::varint;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// Name of the shared reply topic.
pub const REPLY_TOPIC: &str = "railgun.replies";

/// Registered streams, shared between front-end and back-end.
pub type Registry = Arc<RwLock<FxHashMap<String, Arc<StreamDef>>>>;

/// Envelope: what actually travels in an event topic record payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Front-end-assigned ingest id (reply correlation).
    pub ingest_id: u64,
    /// The event.
    pub event: Event,
}

impl Envelope {
    /// Encode with the stream schema.
    pub fn encode(&self, schema: &crate::event::Schema) -> Vec<u8> {
        let mut out = Vec::with_capacity(24);
        varint::write_u64(&mut out, self.ingest_id);
        codec::encode_into(&mut out, &self.event, schema, 0);
        out
    }

    /// Decode with the stream schema.
    pub fn decode(buf: &[u8], schema: &crate::event::Schema) -> Result<Envelope> {
        let mut pos = 0;
        let ingest_id = varint::read_u64(buf, &mut pos)?;
        let event = codec::decode_from(buf, &mut pos, schema, 0)?;
        if pos != buf.len() {
            return Err(Error::corrupt("envelope: trailing bytes"));
        }
        Ok(Envelope { ingest_id, event })
    }
}

/// One metric value inside a reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplyMetric {
    /// Metric name.
    pub name: String,
    /// Rendered group key.
    pub group: String,
    /// Value (None = empty-window identity).
    pub value: Option<f64>,
}

/// A back-end task processor's answer for one event.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplyMsg {
    /// Correlates with [`Envelope::ingest_id`].
    pub ingest_id: u64,
    /// Source topic.
    pub topic: String,
    /// Source partition.
    pub partition: u32,
    /// Event timestamp.
    pub event_ts: i64,
    /// Metric values computed by that task processor.
    pub metrics: Vec<ReplyMetric>,
}

impl ReplyMsg {
    /// JSON encoding (replies are client-facing).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("ingest_id", Json::Int(self.ingest_id as i64)),
            ("topic", Json::Str(self.topic.clone())),
            ("partition", Json::Int(self.partition as i64)),
            ("event_ts", Json::Int(self.event_ts)),
            (
                "metrics",
                Json::Arr(
                    self.metrics
                        .iter()
                        .map(|m| {
                            Json::obj([
                                ("name", Json::Str(m.name.clone())),
                                ("group", Json::Str(m.group.clone())),
                                (
                                    "value",
                                    match m.value {
                                        Some(v) => Json::Float(v),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse from JSON.
    pub fn from_json(json: &Json) -> Result<ReplyMsg> {
        let get = |k: &str| {
            json.get(k)
                .ok_or_else(|| Error::corrupt(format!("reply: missing '{k}'")))
        };
        let metrics = get("metrics")?
            .as_arr()
            .ok_or_else(|| Error::corrupt("reply: 'metrics' not array"))?
            .iter()
            .map(|m| {
                Ok(ReplyMetric {
                    name: m
                        .get("name")
                        .and_then(|j| j.as_str())
                        .ok_or_else(|| Error::corrupt("reply metric: missing name"))?
                        .to_string(),
                    group: m
                        .get("group")
                        .and_then(|j| j.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    value: m.get("value").and_then(|j| j.as_f64()),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ReplyMsg {
            ingest_id: get("ingest_id")?
                .as_i64()
                .ok_or_else(|| Error::corrupt("reply: bad ingest_id"))? as u64,
            topic: get("topic")?
                .as_str()
                .ok_or_else(|| Error::corrupt("reply: bad topic"))?
                .to_string(),
            partition: get("partition")?
                .as_i64()
                .ok_or_else(|| Error::corrupt("reply: bad partition"))? as u32,
            event_ts: get("event_ts")?
                .as_i64()
                .ok_or_else(|| Error::corrupt("reply: bad event_ts"))?,
            metrics,
        })
    }
}

/// Receipt for an ingested event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestReceipt {
    /// Assigned ingest id.
    pub ingest_id: u64,
    /// Number of topic replicas written (= replies to expect).
    pub fanout: u32,
}

/// The front-end: stream registration + event routing.
pub struct FrontEnd {
    broker: BrokerRef,
    producer: Producer,
    registry: Registry,
    partitions_per_topic: u32,
    next_ingest_id: AtomicU64,
}

impl FrontEnd {
    /// Create a front-end over a broker.
    pub fn new(broker: BrokerRef, registry: Registry, partitions_per_topic: u32) -> FrontEnd {
        let producer = broker.producer();
        // seed from wall-clock microseconds so ids never collide across
        // process restarts (replies correlate by ingest_id on a durable
        // reply topic)
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(1)
            << 16;
        FrontEnd {
            broker,
            producer,
            registry,
            partitions_per_topic,
            next_ingest_id: AtomicU64::new(seed),
        }
    }

    /// Register a stream: validates the definition, creates one
    /// partitioned topic per routing entity (+ the reply topic), and
    /// publishes the definition in the shared registry.
    pub fn register_stream(&self, def: StreamDef) -> Result<()> {
        def.validate()?;
        {
            let reg = self.registry.read().unwrap();
            if reg.contains_key(&def.name) {
                return Err(Error::invalid(format!(
                    "stream '{}' already registered",
                    def.name
                )));
            }
        }
        for topic in def.topics() {
            self.broker.ensure_topic(&topic, self.partitions_per_topic)?;
        }
        self.broker.ensure_topic(REPLY_TOPIC, 1)?;
        self.registry
            .write()
            .unwrap()
            .insert(def.name.clone(), Arc::new(def));
        Ok(())
    }

    /// Remove a stream from the registry (topics are retained for replay).
    pub fn deregister_stream(&self, name: &str) -> Result<()> {
        self.registry
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| Error::not_found(format!("stream '{name}'")))
    }

    /// Look up a registered stream.
    pub fn stream(&self, name: &str) -> Result<Arc<StreamDef>> {
        self.registry
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::not_found(format!("stream '{name}'")))
    }

    /// Ingest one event: validate, replicate to every entity topic
    /// (hashed by that entity's value), return the receipt (step 2 of
    /// Figure 2).
    pub fn ingest(&self, stream: &str, event: Event) -> Result<IngestReceipt> {
        let def = self.stream(stream)?;
        def.schema.validate(&event)?;
        let ingest_id = self.next_ingest_id.fetch_add(1, Ordering::Relaxed);
        let env = Envelope { ingest_id, event };
        let payload = env.encode(&def.schema);
        let mut fanout = 0u32;
        for entity in &def.entities {
            let idx = def.schema.index_of(entity).expect("validated");
            let mut key = Vec::with_capacity(24);
            env.event.value(idx).key_bytes(&mut key);
            self.producer.send_keyed(
                &def.topic_for(entity),
                &key,
                env.event.timestamp,
                payload.clone(),
            )?;
            fanout += 1;
        }
        Ok(IngestReceipt { ingest_id, fanout })
    }

    /// Ingest from client JSON.
    pub fn ingest_json(&self, stream: &str, text: &str) -> Result<IngestReceipt> {
        let def = self.stream(stream)?;
        let event = crate::event::json::event_from_json_str(text, &def.schema)?;
        self.ingest(stream, event)
    }

    /// Create a reply collector (its own consumer group so multiple
    /// collectors are independent). The collector starts at the reply
    /// topic's **end**: it only sees replies to events ingested after its
    /// creation (stale replies from previous runs are skipped).
    pub fn reply_collector(&self, group: &str) -> Result<ReplyCollector> {
        self.broker.ensure_topic(REPLY_TOPIC, 1)?;
        let mut consumer = self.broker.consumer(group, &[REPLY_TOPIC])?;
        // force the initial assignment, then seek to the live end
        let _ = consumer.poll(0, Duration::from_millis(0))?;
        for tp in consumer.assignment().to_vec() {
            let end = self.broker.end_offset(&tp)?;
            consumer.seek(tp, end);
        }
        Ok(ReplyCollector {
            consumer,
            pending: FxHashMap::default(),
        })
    }
}

/// Collects reply messages and reassembles per-event answers.
pub struct ReplyCollector {
    consumer: Consumer,
    /// ingest_id → replies received so far.
    pending: FxHashMap<u64, Vec<ReplyMsg>>,
}

impl ReplyCollector {
    /// Drain available replies into the pending map.
    pub fn pump(&mut self, timeout: Duration) -> Result<usize> {
        let polled = self.consumer.poll(1024, timeout)?;
        let n = polled.records.len();
        for (_, rec) in polled.records {
            let text = std::str::from_utf8(&rec.payload)
                .map_err(|e| Error::corrupt(format!("reply: {e}")))?;
            let msg = ReplyMsg::from_json(&Json::parse(text)?)?;
            self.pending.entry(msg.ingest_id).or_default().push(msg);
        }
        Ok(n)
    }

    /// Wait until `expected` replies for `ingest_id` have arrived (step 6
    /// of Figure 2). Returns the replies, removing them from the pending
    /// set.
    pub fn await_event(
        &mut self,
        ingest_id: u64,
        expected: u32,
        timeout: Duration,
    ) -> Result<Vec<ReplyMsg>> {
        let deadline = Instant::now() + timeout;
        loop {
            if self
                .pending
                .get(&ingest_id)
                .map(|v| v.len() >= expected as usize)
                .unwrap_or(false)
            {
                return Ok(self.pending.remove(&ingest_id).unwrap());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::closed(format!(
                    "timed out waiting for {expected} replies to ingest {ingest_id} (have {})",
                    self.pending.get(&ingest_id).map(|v| v.len()).unwrap_or(0)
                )));
            }
            self.pump(deadline - now)?;
        }
    }

    /// Non-blocking: take whatever replies have arrived for an event.
    pub fn take_partial(&mut self, ingest_id: u64) -> Vec<ReplyMsg> {
        self.pending.remove(&ingest_id).unwrap_or_default()
    }

    /// Number of events with outstanding replies.
    pub fn pending_events(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::AggKind;
    use crate::event::Value;
    use crate::mlog::{Broker, BrokerConfig};
    use crate::plan::MetricSpec;
    use crate::window::WindowSpec;
    use crate::workload::payments_schema;

    fn registry() -> Registry {
        Arc::new(RwLock::new(FxHashMap::default()))
    }

    fn def() -> StreamDef {
        StreamDef {
            name: "payments".into(),
            schema: payments_schema(),
            entities: vec!["card".into(), "merchant".into()],
            metrics: vec![
                MetricSpec::new(
                    "sum_by_card",
                    AggKind::Sum,
                    Some("amount"),
                    WindowSpec::sliding(300_000),
                    &["card"],
                ),
                MetricSpec::new(
                    "avg_by_merchant",
                    AggKind::Avg,
                    Some("amount"),
                    WindowSpec::sliding(300_000),
                    &["merchant"],
                ),
            ],
        }
    }

    fn ev(ts: i64, card: &str, merchant: &str, amount: f64) -> Event {
        Event::new(
            ts,
            vec![
                Value::Str(card.into()),
                Value::Str(merchant.into()),
                Value::F64(amount),
                Value::Bool(false),
            ],
        )
    }

    #[test]
    fn envelope_roundtrip() {
        let schema = payments_schema();
        let env = Envelope {
            ingest_id: 42,
            event: ev(1000, "c1", "m1", 9.5),
        };
        let buf = env.encode(&schema);
        assert_eq!(Envelope::decode(&buf, &schema).unwrap(), env);
        assert!(Envelope::decode(&buf[..buf.len() - 1], &schema).is_err());
    }

    #[test]
    fn reply_json_roundtrip() {
        let msg = ReplyMsg {
            ingest_id: 7,
            topic: "payments.card".into(),
            partition: 3,
            event_ts: 123,
            metrics: vec![
                ReplyMetric {
                    name: "sum".into(),
                    group: "c1".into(),
                    value: Some(10.5),
                },
                ReplyMetric {
                    name: "min".into(),
                    group: "c1".into(),
                    value: None,
                },
            ],
        };
        let back = ReplyMsg::from_json(&Json::parse(&msg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn register_creates_topics() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        assert_eq!(broker.partition_count("payments.card"), Some(4));
        assert_eq!(broker.partition_count("payments.merchant"), Some(4));
        assert_eq!(broker.partition_count(REPLY_TOPIC), Some(1));
        assert!(fe.register_stream(def()).is_err(), "duplicate stream");
    }

    #[test]
    fn ingest_replicates_to_entity_topics_keyed_consistently() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        let r1 = fe.ingest("payments", ev(1, "c1", "m1", 5.0)).unwrap();
        assert_eq!(r1.fanout, 2);
        let r2 = fe.ingest("payments", ev(2, "c1", "m2", 6.0)).unwrap();
        assert!(r2.ingest_id > r1.ingest_id);
        // same card ⇒ same partition of the card topic
        let mut c = broker.consumer("g", &["payments.card"]).unwrap();
        let mut partitions = std::collections::HashSet::new();
        loop {
            let p = c.poll(100, Duration::from_millis(10)).unwrap();
            if p.records.is_empty() && p.rebalanced.is_none() {
                break;
            }
            for (tp, rec) in p.records {
                partitions.insert(tp.partition);
                // envelope decodes with the schema
                let env = Envelope::decode(&rec.payload, &payments_schema()).unwrap();
                assert_eq!(env.event.values[0].as_str(), Some("c1"));
            }
        }
        assert_eq!(partitions.len(), 1);
    }

    #[test]
    fn ingest_validates_schema() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker, registry(), 2);
        fe.register_stream(def()).unwrap();
        let bad = Event::new(0, vec![Value::I64(1)]);
        assert!(fe.ingest("payments", bad).is_err());
        assert!(fe.ingest("nope", ev(0, "c", "m", 1.0)).is_err());
    }

    #[test]
    fn ingest_json_end_to_end() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker, registry(), 2);
        fe.register_stream(def()).unwrap();
        let r = fe
            .ingest_json(
                "payments",
                r#"{"timestamp": 5, "card": "c9", "merchant": "m3", "amount": 12.5}"#,
            )
            .unwrap();
        assert_eq!(r.fanout, 2);
        assert!(fe.ingest_json("payments", r#"{"card": "c9"}"#).is_err());
    }

    #[test]
    fn reply_collector_assembles() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2);
        fe.register_stream(def()).unwrap();
        let mut rc = fe.reply_collector("collector").unwrap();
        // simulate two task processors replying for ingest 5
        let producer = broker.producer();
        for (topic, p) in [("payments.card", 0u32), ("payments.merchant", 1u32)] {
            let msg = ReplyMsg {
                ingest_id: 5,
                topic: topic.into(),
                partition: p,
                event_ts: 1,
                metrics: vec![],
            };
            producer
                .send(REPLY_TOPIC, 0, 1, vec![], msg.to_json().to_string().into_bytes())
                .unwrap();
        }
        let replies = rc.await_event(5, 2, Duration::from_secs(5)).unwrap();
        assert_eq!(replies.len(), 2);
        assert_eq!(rc.pending_events(), 0);
        // timeout on missing event
        assert!(rc.await_event(99, 1, Duration::from_millis(30)).is_err());
    }
}
