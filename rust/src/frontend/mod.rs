//! Front-end layer (paper §3.2): client entry point, event routing and
//! reply collection.
//!
//! On ingest, an event is **replicated to one topic per routing entity**
//! of its stream, partitioned by the hash of that entity's value — this
//! is what guarantees the processing unit computing a metric sees *every*
//! event of its group (accuracy requirement A). The front-end also owns
//! the reply topic: back-end task processors publish their metric values
//! there, and [`ReplyCollector`] reassembles the per-event answer for the
//! client (steps 5–6 of Figure 2).
//!
//! The ingest path is **batch-first and raw-first**:
//! [`FrontEnd::ingest_batch_raw`] takes pre-encoded value bytes
//! ([`RawEvent`]s — what the net server's v2 wire decode hands over),
//! validates each with one [`codec::scan_values`] walk, splices the
//! ingest id + timestamp varints in front of them to form the envelope
//! payload (shared `Arc<[u8]>`-backed across that event's entity-topic
//! replicas), reads entity keys through a borrowed [`EventView`] into
//! one batch-wide key buffer, groups the replicas by (topic, partition)
//! and issues **one producer append per partition**. The owned-event
//! [`FrontEnd::ingest_batch`] encodes into a scratch buffer and
//! delegates — one routing implementation, byte-identical output — and
//! [`FrontEnd::ingest`] is its single-event special case. Batching is
//! purely a transport/amortization concern — the back-end still
//! evaluates every window at every event timestamp, so per-event
//! accuracy is untouched.
//!
//! Replies travel in the varint binary codec (same family as the event
//! codec), one record per (task-processor, batch) with multiple
//! [`ReplyMsg`]s per record; [`ReplyMsg::to_json`] remains for
//! client-facing rendering only.
//!
//! ## Exactly-once ingest: the idempotent-producer dedup table
//!
//! The net server publishes through [`FrontEnd::ingest_batch_raw_tagged`],
//! which keys every batch by `(producer_id, batch_seq)` — the identity
//! HELLO negotiates (see [`crate::net::wire`]) plus the per-producer
//! sequence number on the ingest frame. The pair is packed into the
//! [`crate::mlog::Record::seq`] tag of every record the batch publishes,
//! so the dedup state is persisted *inside the data itself*: recovery
//! replays the log anyway, and [`crate::mlog::Broker::recovered_producers`]
//! hands back each producer's durable high-water for free. A retried
//! batch is classified **before** publication — fresh seqs publish
//! normally; exact duplicates are acked (`duplicate = true`) with the
//! original id range and never touch the mlog; a batch whose first
//! attempt died between partitions is *completed*, appending only the
//! records missing from durable storage under the original ingest ids,
//! byte-identical to what the first attempt would have written. The
//! fast path adds one per-producer mutex and zero allocations to a
//! fresh batch; the reconstruction paths are retry-only.

use crate::config::StreamDef;
use crate::error::{Error, Result};
use crate::event::{codec, Event, EventView, RawBatchBuf, RawEvent, ViewScratch};
use crate::mlog::{BatchEntry, BrokerRef, Consumer, Payload, Producer};
use crate::telemetry::Telemetry;
use crate::util::hash;
use crate::util::hash::FxHashMap;
use crate::util::json::Json;
use crate::util::varint;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Name of the shared reply topic.
pub const REPLY_TOPIC: &str = "railgun.replies";

/// Reply-topic partition an ingest id routes to.
///
/// The reply topic is sharded (`EngineConfig::reply_partitions`) and
/// replies are routed by ingest id so multiple collectors — and the net
/// server's per-connection reply streams — scale across partitions.
/// Front-end ingest ids are assigned contiguously, so the modulo spreads
/// consecutive events round-robin over the shards.
#[inline]
pub fn reply_partition_for(ingest_id: u64, partitions: u32) -> u32 {
    if partitions <= 1 {
        0
    } else {
        (ingest_id % partitions as u64) as u32
    }
}

/// Registered streams, shared between front-end and back-end.
pub type Registry = Arc<RwLock<FxHashMap<String, Arc<StreamDef>>>>;

/// Envelope: what actually travels in an event topic record payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Front-end-assigned ingest id (reply correlation).
    pub ingest_id: u64,
    /// The event.
    pub event: Event,
}

impl Envelope {
    /// Encode with the stream schema.
    pub fn encode(&self, schema: &crate::event::Schema) -> Vec<u8> {
        let mut out = Vec::with_capacity(24);
        varint::write_u64(&mut out, self.ingest_id);
        codec::encode_into(&mut out, &self.event, schema, 0);
        out
    }

    /// Encode an envelope payload directly from raw parts — the ingest
    /// id and timestamp varints spliced in front of already-encoded
    /// value bytes. Byte-identical to [`Envelope::encode`] for the same
    /// event, with no `Event` in sight: this is how the raw ingest path
    /// carries a client's encoded bytes to the reservoir untouched.
    pub fn encode_raw(ingest_id: u64, timestamp: i64, values: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + values.len());
        varint::write_u64(&mut out, ingest_id);
        varint::write_i64(&mut out, timestamp);
        out.extend_from_slice(values);
        out
    }

    /// Decode with the stream schema.
    pub fn decode(buf: &[u8], schema: &crate::event::Schema) -> Result<Envelope> {
        let mut pos = 0;
        let ingest_id = varint::read_u64(buf, &mut pos)?;
        let event = codec::decode_from(buf, &mut pos, schema, 0)?;
        if pos != buf.len() {
            return Err(Error::corrupt("envelope: trailing bytes"));
        }
        Ok(Envelope { ingest_id, event })
    }

    /// Borrowed decode: ingest id + an [`EventView`] over the payload —
    /// validates exactly what [`Envelope::decode`] validates without
    /// materializing an `Event`. This is the envelope framing contract
    /// the back-end's zero-allocation ingest relies on: the bytes after
    /// the ingest-id varint are one standalone-encoded event
    /// (`timestamp varint ++ value section`), so the value section can be
    /// spliced straight into a reservoir chunk.
    pub fn view<'a>(
        buf: &'a [u8],
        schema: &'a crate::event::Schema,
        scratch: &'a mut ViewScratch,
    ) -> Result<(u64, EventView<'a>)> {
        let mut pos = 0;
        let ingest_id = varint::read_u64(buf, &mut pos)?;
        let view = scratch.view_from(buf, &mut pos, schema, 0)?;
        if pos != buf.len() {
            return Err(Error::corrupt("envelope: trailing bytes"));
        }
        Ok((ingest_id, view))
    }

    /// Split an envelope payload into `(ingest_id, timestamp,
    /// value_bytes)` without touching the value section — the back-end
    /// hands `value_bytes` to the reservoir's raw-append path, which
    /// validates it as it builds its field-offset table (one scan total).
    #[inline]
    pub fn split_raw(buf: &[u8]) -> Result<(u64, i64, &[u8])> {
        let mut pos = 0;
        let ingest_id = varint::read_u64(buf, &mut pos)?;
        let ts = varint::read_i64(buf, &mut pos)?;
        Ok((ingest_id, ts, &buf[pos..]))
    }
}

/// One metric value inside a reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplyMetric {
    /// Metric name.
    pub name: String,
    /// Rendered group key.
    pub group: String,
    /// Value (None = empty-window identity).
    pub value: Option<f64>,
}

/// A back-end task processor's answer for one event.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplyMsg {
    /// Correlates with [`Envelope::ingest_id`].
    pub ingest_id: u64,
    /// Source topic.
    pub topic: String,
    /// Source partition.
    pub partition: u32,
    /// Event timestamp.
    pub event_ts: i64,
    /// Metric values computed by that task processor.
    pub metrics: Vec<ReplyMetric>,
}

impl ReplyMsg {
    /// Streaming encoder: append one reply message built from parts,
    /// without materializing a `ReplyMsg` (owned `String`s). This is the
    /// task processors' zero-allocation reply path — metric/group names
    /// arrive as borrowed `&str`s resolved from the plan's interner.
    /// [`ReplyMsg::encode_into`] delegates here, so the two encodings can
    /// never drift: the wire format stays byte-identical.
    pub fn encode_parts<'m>(
        out: &mut Vec<u8>,
        ingest_id: u64,
        topic: &str,
        partition: u32,
        event_ts: i64,
        metrics: impl ExactSizeIterator<Item = (&'m str, &'m str, Option<f64>)>,
    ) {
        varint::write_u64(out, ingest_id);
        varint::write_str(out, topic);
        varint::write_u32(out, partition);
        varint::write_i64(out, event_ts);
        varint::write_u64(out, metrics.len() as u64);
        for (name, group, value) in metrics {
            varint::write_str(out, name);
            varint::write_str(out, group);
            match value {
                Some(v) => {
                    out.push(1);
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
                None => out.push(0),
            }
        }
    }

    /// Append the varint binary encoding (the on-wire reply format; the
    /// same codec family the event envelopes use).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        Self::encode_parts(
            out,
            self.ingest_id,
            &self.topic,
            self.partition,
            self.event_ts,
            self.metrics
                .iter()
                .map(|m| (m.name.as_str(), m.group.as_str(), m.value)),
        );
    }

    /// Decode one message from `buf` at `*pos`, advancing `*pos`.
    pub fn decode_from(buf: &[u8], pos: &mut usize) -> Result<ReplyMsg> {
        let ingest_id = varint::read_u64(buf, pos)?;
        let topic = varint::read_str(buf, pos)?.to_string();
        let partition = varint::read_u32(buf, pos)?;
        let event_ts = varint::read_i64(buf, pos)?;
        let n = varint::read_u64(buf, pos)? as usize;
        let mut metrics = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let name = varint::read_str(buf, pos)?.to_string();
            let group = varint::read_str(buf, pos)?.to_string();
            let present = *buf
                .get(*pos)
                .ok_or_else(|| Error::corrupt("reply: truncated value marker"))?;
            *pos += 1;
            let value = match present {
                0 => None,
                1 => {
                    let end = *pos + 8;
                    let bytes = buf
                        .get(*pos..end)
                        .ok_or_else(|| Error::corrupt("reply: truncated f64"))?;
                    *pos = end;
                    Some(f64::from_bits(u64::from_le_bytes(
                        bytes.try_into().expect("8-byte slice"),
                    )))
                }
                t => return Err(Error::corrupt(format!("reply: bad value marker {t}"))),
            };
            metrics.push(ReplyMetric { name, group, value });
        }
        Ok(ReplyMsg {
            ingest_id,
            topic,
            partition,
            event_ts,
            metrics,
        })
    }

    /// Encode a batch of replies as one reply-topic record payload
    /// (messages are simply concatenated; the codec is self-delimiting).
    pub fn encode_batch(msgs: &[ReplyMsg]) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 * msgs.len());
        for m in msgs {
            m.encode_into(&mut out);
        }
        out
    }

    /// Decode every message of a reply-topic record payload.
    pub fn decode_batch(buf: &[u8]) -> Result<Vec<ReplyMsg>> {
        let mut out = Vec::new();
        let mut pos = 0;
        while pos < buf.len() {
            out.push(ReplyMsg::decode_from(buf, &mut pos)?);
        }
        Ok(out)
    }

    /// JSON rendering (client-facing output only — the wire format is
    /// [`ReplyMsg::encode_batch`]).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("ingest_id", Json::Int(self.ingest_id as i64)),
            ("topic", Json::Str(self.topic.clone())),
            ("partition", Json::Int(self.partition as i64)),
            ("event_ts", Json::Int(self.event_ts)),
            (
                "metrics",
                Json::Arr(
                    self.metrics
                        .iter()
                        .map(|m| {
                            Json::obj([
                                ("name", Json::Str(m.name.clone())),
                                ("group", Json::Str(m.group.clone())),
                                (
                                    "value",
                                    match m.value {
                                        Some(v) => Json::Float(v),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One (entity, partition) replica of a raw-ingested event, pointing at
/// the batch's shared payload vec and interned-key table — replicas
/// carry no owned bytes of their own.
struct Replica {
    /// Index into the batch's events/payloads.
    event: u32,
    /// Index into the batch's interned key table (`key_arcs`).
    key: u32,
}

/// Receipt for an ingested event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestReceipt {
    /// Assigned ingest id.
    pub ingest_id: u64,
    /// Number of topic replicas written (= replies to expect).
    pub fanout: u32,
}

/// Outcome of a tagged (idempotent-producer) ingest: everything an
/// INGEST_ACK needs, whether the batch published fresh, completed a
/// partial earlier attempt, or deduplicated entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngestOutcome {
    /// First ingest id of the batch — the *original* assignment on
    /// every retry path, so acks are authoritative across resends.
    pub first_ingest_id: u64,
    /// Events in the batch.
    pub count: u32,
    /// Replies to expect per event.
    pub fanout: u32,
    /// The batch was already fully published; nothing was appended now.
    pub duplicate: bool,
}

/// How many completed batches each producer remembers exactly as
/// `(seq, first_id, count)` triples. A duplicate older than the ring
/// falls back to the durable-tag slow path, which reconstructs the same
/// answer from the mlog records.
const DONE_RECENT: usize = 1024;

/// In-memory dedup state for one idempotent producer. The durable
/// source of truth is the seq tag on the mlog records themselves
/// ([`crate::mlog::Record::seq`]); this is the fast path over it.
struct ProducerState {
    /// Authoritative session epoch (echoed to the client on HELLO_OK).
    epoch: u32,
    /// Highest batch seq ever attempted — the fresh/duplicate boundary.
    max_seen: u32,
    /// Whether `max_seen` reflects this producer's durable history. An
    /// entry recreated after a cap eviction (or a cold resume) starts
    /// unseeded and is re-seeded from the record tags
    /// ([`crate::mlog::Broker::producer_high_water`]) before its first
    /// batch classifies — so eviction never weakens exactly-once.
    seeded: bool,
    /// Last batch/registration touch; the eviction clock.
    last_used: Instant,
    /// Batches whose publish failed after ids were assigned, as
    /// `(seq, first_id, count)`: a retry completes the missing suffix
    /// under the same ids.
    gaps: Vec<(u32, u64, u32)>,
    /// Recently completed batches, newest at the back. Bounded ring —
    /// full capacity up front, so completing a batch never reallocates.
    done_recent: VecDeque<(u32, u64, u32)>,
}

impl ProducerState {
    fn new(epoch: u32, max_seen: u32) -> ProducerState {
        ProducerState {
            epoch,
            max_seen,
            seeded: true,
            last_used: Instant::now(),
            gaps: Vec::new(),
            done_recent: VecDeque::with_capacity(DONE_RECENT),
        }
    }

    /// A recreated entry whose durable history is not yet known.
    fn unseeded(epoch: u32) -> ProducerState {
        ProducerState {
            seeded: false,
            ..ProducerState::new(epoch, 0)
        }
    }

    fn record_done(&mut self, seq: u32, first_id: u64, count: u32) {
        if self.done_recent.len() == DONE_RECENT {
            self.done_recent.pop_front();
        }
        self.done_recent.push_back((seq, first_id, count));
    }

    fn done(&self, seq: u32) -> Option<(u64, u32)> {
        self.done_recent
            .iter()
            .rev()
            .find(|d| d.0 == seq)
            .map(|d| (d.1, d.2))
    }
}

/// One (entity-topic, partition) group of a tagged batch's replicas,
/// with how much of it is already durable under the batch tag.
struct TaggedGroup {
    /// Entity index (= index into `def.topics()`).
    topic: usize,
    partition: u32,
    /// Event indices in publication order (input order).
    entries: Vec<u32>,
    /// Records already durable under the tag — always a *prefix* of
    /// `entries`, because groups publish in order.
    durable: u64,
    /// Payload of the earliest durable record, for id recovery.
    earliest: Option<Payload>,
}

/// Recover a batch's original first ingest id from the earliest durable
/// record of any group: that record is the group's first entry, so its
/// envelope id minus the entry's event index is the batch's first id.
/// `None` when no group has any durable record.
fn original_first_id(groups: &[TaggedGroup]) -> Result<Option<u64>> {
    for g in groups {
        if let Some(p) = &g.earliest {
            let (env_id, _, _) = Envelope::split_raw(p)?;
            let event0 = g.entries[0] as u64;
            let first = env_id.checked_sub(event0).ok_or_else(|| {
                Error::internal(format!(
                    "tagged record carries ingest id {env_id} below its event index {event0}"
                ))
            })?;
            return Ok(Some(first));
        }
    }
    Ok(None)
}

/// The front-end: stream registration + event routing.
pub struct FrontEnd {
    broker: BrokerRef,
    producer: Producer,
    registry: Registry,
    partitions_per_topic: u32,
    /// Reply-topic shard count (config `reply_partitions`).
    reply_partitions: u32,
    /// Max records per producer append batch (config `ingest_batch`).
    ingest_batch: usize,
    next_ingest_id: AtomicU64,
    /// Idempotent-producer dedup table: producer id → state. The outer
    /// lock is held only to fetch the per-producer `Arc`; the
    /// per-producer lock is held across classify+publish, serializing
    /// batches of one producer while distinct producers publish in
    /// parallel.
    producers: Mutex<FxHashMap<u32, Arc<Mutex<ProducerState>>>>,
    /// Next fresh producer id — seeded past every id recovered from the
    /// mlog so a restart never re-issues a live identity.
    next_producer_id: AtomicU32,
    /// Max producers kept in the dedup table (config
    /// `dedup_producer_cap`; 0 = unbounded). Past it, the longest-idle
    /// entry is evicted and counted in `frontend.dedup_evicted`.
    dedup_producer_cap: usize,
    /// Engine telemetry registry; routing records batch/event/interner
    /// counters into it (relaxed adds on per-batch accumulators — the
    /// per-event path stays allocation- and barrier-free).
    telemetry: Arc<Telemetry>,
}

impl FrontEnd {
    /// Create a front-end over a broker.
    pub fn new(broker: BrokerRef, registry: Registry, partitions_per_topic: u32) -> FrontEnd {
        let producer = broker.producer();
        // seed from wall-clock microseconds so ids never collide across
        // process restarts (replies correlate by ingest_id on a durable
        // reply topic)
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(1)
            << 16;
        // rebuild the dedup table from the record tags the broker
        // replayed: a producer resuming after our restart keeps its
        // durable high-water, so resent batches classify as duplicates
        let mut producers = FxHashMap::default();
        let mut max_pid = 0u32;
        for (pid, max_seq) in broker.recovered_producers() {
            max_pid = max_pid.max(pid);
            producers.insert(pid, Arc::new(Mutex::new(ProducerState::new(1, max_seq))));
        }
        FrontEnd {
            broker,
            producer,
            registry,
            partitions_per_topic,
            reply_partitions: 1,
            ingest_batch: 256,
            next_ingest_id: AtomicU64::new(seed),
            producers: Mutex::new(producers),
            next_producer_id: AtomicU32::new(max_pid + 1),
            dedup_producer_cap: 65_536,
            telemetry: Arc::new(Telemetry::new()),
        }
    }

    /// Bound the dedup table (the engine config's `dedup_producer_cap`
    /// knob; 0 = unbounded).
    pub fn with_dedup_producer_cap(mut self, cap: usize) -> FrontEnd {
        self.dedup_producer_cap = cap;
        self
    }

    /// Cap the number of records per producer append batch (the engine
    /// config's `ingest_batch` knob; values below 1 are clamped to 1).
    pub fn with_ingest_batch(mut self, ingest_batch: usize) -> FrontEnd {
        self.ingest_batch = ingest_batch.max(1);
        self
    }

    /// Shard count for the reply topic (the engine config's
    /// `reply_partitions` knob; values below 1 are clamped to 1). Only
    /// effective for the process that first creates the reply topic —
    /// later frontends adopt the existing shard count.
    pub fn with_reply_partitions(mut self, reply_partitions: u32) -> FrontEnd {
        self.reply_partitions = reply_partitions.max(1);
        self
    }

    /// Share an engine-wide telemetry registry (the coordinator wires
    /// the node's registry in; a default front-end carries its own).
    pub fn with_telemetry(mut self, telemetry: Arc<Telemetry>) -> FrontEnd {
        self.telemetry = telemetry;
        self
    }

    /// The telemetry registry this front-end records into (shared with
    /// the net server and, through the coordinator, every stage).
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.telemetry.clone()
    }

    /// Configured reply-topic shard count.
    pub fn reply_partitions(&self) -> u32 {
        self.reply_partitions
    }

    /// Register a stream: validates the definition, creates one
    /// partitioned topic per routing entity (+ the reply topic), and
    /// publishes the definition in the shared registry.
    pub fn register_stream(&self, def: StreamDef) -> Result<()> {
        def.validate()?;
        {
            let reg = self.registry.read().unwrap();
            if reg.contains_key(&def.name) {
                return Err(Error::invalid(format!(
                    "stream '{}' already registered",
                    def.name
                )));
            }
        }
        for topic in def.topics() {
            self.broker.ensure_topic(&topic, self.partitions_per_topic)?;
        }
        self.broker.ensure_topic(REPLY_TOPIC, self.reply_partitions)?;
        self.registry
            .write()
            .unwrap()
            .insert(def.name.clone(), Arc::new(def));
        Ok(())
    }

    /// Remove a stream from the registry (topics are retained for replay).
    pub fn deregister_stream(&self, name: &str) -> Result<()> {
        self.registry
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| Error::not_found(format!("stream '{name}'")))
    }

    /// Look up a registered stream.
    pub fn stream(&self, name: &str) -> Result<Arc<StreamDef>> {
        self.registry
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::not_found(format!("stream '{name}'")))
    }

    /// Ingest one event: validate, replicate to every entity topic
    /// (hashed by that entity's value), return the receipt (step 2 of
    /// Figure 2). Literally the single-event case of
    /// [`FrontEnd::ingest_batch`] — one routing implementation, so the
    /// per-event and batched paths can never drift.
    pub fn ingest(&self, stream: &str, event: Event) -> Result<IngestReceipt> {
        let receipts = self.ingest_batch(stream, vec![event])?;
        Ok(*receipts.first().expect("one event in, one receipt out"))
    }

    /// Ingest a batch of events in one pass (the batch-first hot path):
    /// every envelope is validated and encoded **once**, replicas share
    /// the payload bytes across entity topics, and the records are
    /// grouped by (topic, partition) so each partition sees **one**
    /// append (at most `ingest_batch` records each) instead of one per
    /// event.
    ///
    /// Semantically identical to calling [`FrontEnd::ingest`] per event —
    /// per-partition record order follows the input order, and the
    /// back-end still evaluates every window at every event timestamp —
    /// it only amortizes locking, allocation and encoding.
    ///
    /// Failure semantics: publication is not atomic across partitions
    /// (exactly like the messaging layer it sits on). Groups are
    /// appended in deterministic (entity, partition) order; if an append
    /// errors, a prefix of the groups may already be durable. Callers on
    /// this **untagged** path that retry re-publish those events under
    /// fresh ingest ids; the net server's tagged path
    /// ([`FrontEnd::ingest_batch_raw_tagged`]) closes exactly that hole —
    /// a retried `(producer_id, batch_seq)` re-publishes only the
    /// missing suffix under the original ids.
    pub fn ingest_batch(&self, stream: &str, events: Vec<Event>) -> Result<Vec<IngestReceipt>> {
        let first_id = self.reserve_ingest_ids(events.len() as u64);
        self.ingest_batch_reserved(stream, events, first_id)
    }

    /// Reserve `count` contiguous ingest ids without publishing anything.
    ///
    /// Lets a caller know a batch's id range **before** the events hit
    /// the messaging layer — the net server uses this to register its
    /// reply routes first, so a reply can never race the registration.
    /// Ids burned on a batch that later fails validation are simply
    /// never used.
    pub fn reserve_ingest_ids(&self, count: u64) -> u64 {
        self.next_ingest_id.fetch_add(count, Ordering::Relaxed)
    }

    /// [`FrontEnd::ingest_batch`] with a caller-reserved id range (from
    /// [`FrontEnd::reserve_ingest_ids`] with `events.len()`). Owned
    /// events are validated, their value sections encoded **once** into
    /// a scratch buffer, and the batch delegated to the raw path — one
    /// routing implementation, so the owned, raw and per-event paths can
    /// never drift.
    pub fn ingest_batch_reserved(
        &self,
        stream: &str,
        events: Vec<Event>,
        first_id: u64,
    ) -> Result<Vec<IngestReceipt>> {
        let def = self.stream(stream)?;
        if events.is_empty() {
            return Ok(Vec::new());
        }
        self.telemetry.frontend.owned_batches.incr();
        for event in &events {
            def.schema.validate(event)?;
        }
        let mut batch = RawBatchBuf::new();
        for event in &events {
            batch.push(event, &def.schema);
        }
        self.ingest_batch_raw_reserved(stream, &batch.raws(), first_id)
    }

    /// Ingest a batch of **pre-encoded** events ([`RawEvent`]s) in one
    /// pass — the raw counterpart of [`FrontEnd::ingest_batch`] and the
    /// terminus of the wire's raw ingest path: each event's value bytes
    /// are validated with one [`codec::scan_values`] walk (reject set
    /// identical to the owned decoder's), the ingest id and timestamp
    /// varints are spliced in front of them to form the envelope
    /// payload, and entity keys are read through a borrowed
    /// [`EventView`] and interned into per-batch shared `Arc<[u8]>`s —
    /// no owned `Event`, `Vec<Value>` or `String` is materialized
    /// anywhere, and a repeated key allocates once per batch.
    ///
    /// Output is byte-for-byte identical to the owned path for the same
    /// events: envelope payloads, record keys, partition assignment and
    /// per-partition order all match (`ingest_batch_raw_matches_owned_
    /// batch_bytes` asserts it).
    pub fn ingest_batch_raw(
        &self,
        stream: &str,
        events: &[RawEvent<'_>],
    ) -> Result<Vec<IngestReceipt>> {
        if !events.is_empty() {
            self.telemetry.frontend.raw_batches.incr();
        }
        let first_id = self.reserve_ingest_ids(events.len() as u64);
        self.ingest_batch_raw_reserved(stream, events, first_id)
    }

    /// [`FrontEnd::ingest_batch_raw`] with a caller-reserved id range —
    /// what the net server calls after registering its reply routes.
    /// The whole batch is validated before anything publishes (same
    /// contract as the owned path); failure semantics are those of
    /// [`FrontEnd::ingest_batch`].
    pub fn ingest_batch_raw_reserved(
        &self,
        stream: &str,
        events: &[RawEvent<'_>],
        first_id: u64,
    ) -> Result<Vec<IngestReceipt>> {
        let def = self.stream(stream)?;
        if events.is_empty() {
            return Ok(Vec::new());
        }
        let arity = def.schema.len();
        // one validating walk per event, all before anything publishes;
        // the recorded offsets double as the views' field tables below
        let mut offsets: Vec<u32> = Vec::with_capacity(events.len() * arity);
        for (i, re) in events.iter().enumerate() {
            let mut pos = 0usize;
            codec::scan_values(re.values, &mut pos, &def.schema, &mut offsets)
                .map_err(|e| Error::invalid(format!("event {i}: {e}")))?;
            if pos != re.values.len() {
                return Err(Error::invalid(format!(
                    "event {i}: {} trailing value bytes",
                    re.values.len() - pos
                )));
            }
        }
        self.route_raw_batch(&def, events, first_id, &offsets, 0)
    }

    /// Register (or resume) an idempotent-producer session. `(0, 0)`
    /// mints a fresh identity; a non-zero id resumes the state recorded
    /// for it — in memory if the producer is known, otherwise a fresh
    /// entry whose history the durable record tags reconstruct on
    /// demand. Returns the authoritative `(producer_id, epoch)` that
    /// HELLO_OK carries.
    pub fn register_producer(&self, producer_id: u32, epoch: u32) -> (u32, u32) {
        let mut table = self.producers.lock().unwrap();
        let out = if producer_id == 0 {
            let pid = self.next_producer_id.fetch_add(1, Ordering::Relaxed);
            // a freshly minted id has no durable history: born seeded
            table.insert(pid, Arc::new(Mutex::new(ProducerState::new(1, 0))));
            (pid, 1)
        } else {
            // never hand a fresh session this resumed id later
            self.next_producer_id
                .fetch_max(producer_id.saturating_add(1), Ordering::Relaxed);
            let state = table
                .entry(producer_id)
                .or_insert_with(|| Arc::new(Mutex::new(ProducerState::unseeded(epoch.max(1)))));
            let mut ps = state.lock().unwrap();
            ps.last_used = Instant::now();
            (producer_id, ps.epoch)
        };
        self.evict_idle_producers(&mut table, out.0);
        out
    }

    /// Evict longest-idle producers while the dedup table exceeds
    /// `dedup_producer_cap` (0 = unbounded). `keep` — the entry just
    /// touched — and any entry whose lock is held (a batch in flight)
    /// are never evicted. Dedup stays exact across eviction: the
    /// durable record tags remain the source of truth, and a returning
    /// evicted producer re-seeds from them before classifying.
    fn evict_idle_producers(
        &self,
        table: &mut FxHashMap<u32, Arc<Mutex<ProducerState>>>,
        keep: u32,
    ) {
        let cap = self.dedup_producer_cap;
        if cap == 0 {
            return;
        }
        while table.len() > cap {
            let mut oldest: Option<(u32, Instant)> = None;
            for (&pid, state) in table.iter() {
                if pid == keep {
                    continue;
                }
                if let Ok(ps) = state.try_lock() {
                    if oldest.map(|(_, t)| ps.last_used < t).unwrap_or(true) {
                        oldest = Some((pid, ps.last_used));
                    }
                }
            }
            match oldest {
                Some((pid, _)) => {
                    table.remove(&pid);
                    self.telemetry.frontend.dedup_evicted.incr();
                }
                None => break, // everything busy; retry on a later insert
            }
        }
    }

    /// Ingest a raw batch under an idempotent-producer tag — the net
    /// server's publish path for both wire versions. Exactly-once per
    /// `(producer_id, batch_seq)`: a fresh seq publishes and records
    /// its id range; a retried seq re-publishes **only the records
    /// missing from durable storage** (same ids, byte-identical
    /// payloads) or nothing at all; the outcome always reports the
    /// original `first_ingest_id`.
    ///
    /// `before_publish(first_id, count, fanout)` runs once the id range
    /// is known and before anything is appended — the server registers
    /// its reply routes there, so replies (including stashed replies
    /// from a failed first attempt) can never race the registration.
    ///
    /// `offsets` is the prevalidated scan table of `events` (one
    /// schema-arity run per event, each relative to that event's value
    /// slice, produced by a successful [`codec::scan_values`] over
    /// exactly those bytes — the wire decode's
    /// [`crate::net::wire::decode_raw_batch_offsets`] walk qualifies,
    /// closing the v2 double-scan). Pass `None` to validate here.
    pub fn ingest_batch_raw_tagged(
        &self,
        stream: &str,
        producer_id: u32,
        batch_seq: u64,
        events: &[RawEvent<'_>],
        offsets: Option<&[u32]>,
        before_publish: &mut dyn FnMut(u64, u32, u32),
    ) -> Result<IngestOutcome> {
        let def = self.stream(stream)?;
        if producer_id == 0 {
            return Err(Error::invalid("tagged ingest without a registered producer"));
        }
        if batch_seq == 0 || batch_seq > u32::MAX as u64 {
            return Err(Error::invalid(format!(
                "batch seq {batch_seq} outside 1..={}",
                u32::MAX
            )));
        }
        let arity = def.schema.len();
        let validated: Option<Vec<u32>> = match offsets {
            Some(o) => {
                if o.len() != events.len() * arity {
                    return Err(Error::internal(format!(
                        "tagged ingest: offset table holds {} entries, expected {}",
                        o.len(),
                        events.len() * arity
                    )));
                }
                None
            }
            None => {
                let mut scanned: Vec<u32> = Vec::with_capacity(events.len() * arity);
                for (i, re) in events.iter().enumerate() {
                    let mut pos = 0usize;
                    codec::scan_values(re.values, &mut pos, &def.schema, &mut scanned)
                        .map_err(|e| Error::invalid(format!("event {i}: {e}")))?;
                    if pos != re.values.len() {
                        return Err(Error::invalid(format!(
                            "event {i}: {} trailing value bytes",
                            re.values.len() - pos
                        )));
                    }
                }
                Some(scanned)
            }
        };
        let offs: &[u32] = offsets.unwrap_or_else(|| validated.as_deref().expect("scanned above"));
        let count = events.len() as u32;
        let fanout = def.entities.len() as u32;
        let seq32 = batch_seq as u32;
        let tag = (producer_id as u64) << 32 | seq32 as u64;

        let state = {
            let mut table = self.producers.lock().unwrap();
            let state = table
                .entry(producer_id)
                .or_insert_with(|| Arc::new(Mutex::new(ProducerState::unseeded(1))))
                .clone();
            self.evict_idle_producers(&mut table, producer_id);
            state
        };
        // held across classify + publish: one producer's batches are
        // serialized, so a retry can never race its original attempt
        let mut ps = state.lock().unwrap();
        ps.last_used = Instant::now();
        if !ps.seeded {
            // recreated after a cap eviction (or a cold resume): recover
            // the durable high-water from the record tags before
            // classifying, so a replayed duplicate can never publish
            ps.max_seen = ps.max_seen.max(self.broker.producer_high_water(producer_id)?);
            ps.seeded = true;
        }

        if events.is_empty() {
            // nothing to publish or dedup; ack an empty id range and
            // leave the seq state untouched
            let first_id = self.reserve_ingest_ids(0);
            before_publish(first_id, 0, fanout);
            return Ok(IngestOutcome {
                first_ingest_id: first_id,
                count: 0,
                fanout,
                duplicate: false,
            });
        }

        if seq32 > ps.max_seen {
            // fresh — the fast path (no allocation beyond the publish)
            ps.max_seen = seq32;
            self.telemetry.frontend.raw_batches.incr();
            let first_id = self.reserve_ingest_ids(events.len() as u64);
            before_publish(first_id, count, fanout);
            return match self.route_raw_batch(&def, events, first_id, offs, tag) {
                Ok(_) => {
                    ps.record_done(seq32, first_id, count);
                    Ok(IngestOutcome {
                        first_ingest_id: first_id,
                        count,
                        fanout,
                        duplicate: false,
                    })
                }
                Err(e) => {
                    // a prefix of the groups may be durable; remember
                    // the id range so the retry completes, not re-issues
                    ps.gaps.push((seq32, first_id, count));
                    Err(e)
                }
            };
        }

        if let Some(i) = ps.gaps.iter().position(|g| g.0 == seq32) {
            // known-failed: complete the missing suffix under the
            // original ids
            let (_, first_id, orig_count) = ps.gaps[i];
            if orig_count != count {
                return Err(Error::invalid(format!(
                    "retry of batch seq {seq32} with {count} events, originally {orig_count}"
                )));
            }
            before_publish(first_id, count, fanout);
            let groups = self.tagged_groups(&def, events, offs, tag)?;
            let published = self.complete_groups(&def, events, offs, first_id, tag, &groups)?;
            ps.gaps.swap_remove(i);
            ps.record_done(seq32, first_id, count);
            return Ok(IngestOutcome {
                first_ingest_id: first_id,
                count,
                fanout,
                duplicate: published == 0,
            });
        }

        if let Some((first_id, orig_count)) = ps.done(seq32) {
            // exact duplicate of a completed batch: never touches the mlog
            if orig_count != count {
                return Err(Error::invalid(format!(
                    "duplicate of batch seq {seq32} with {count} events, originally {orig_count}"
                )));
            }
            self.telemetry.frontend.dedup_hits.incr();
            before_publish(first_id, count, fanout);
            return Ok(IngestOutcome {
                first_ingest_id: first_id,
                count,
                fanout,
                duplicate: true,
            });
        }

        // below the high water with no in-memory record — a duplicate
        // from before a restart, or older than the done ring: rebuild
        // the truth from the durable record tags
        let groups = self.tagged_groups(&def, events, offs, tag)?;
        match original_first_id(&groups)? {
            None => {
                // no durable trace: the original attempt published
                // nothing — publish as if fresh
                self.telemetry.frontend.raw_batches.incr();
                let first_id = self.reserve_ingest_ids(events.len() as u64);
                before_publish(first_id, count, fanout);
                match self.route_raw_batch(&def, events, first_id, offs, tag) {
                    Ok(_) => {
                        ps.record_done(seq32, first_id, count);
                        Ok(IngestOutcome {
                            first_ingest_id: first_id,
                            count,
                            fanout,
                            duplicate: false,
                        })
                    }
                    Err(e) => {
                        ps.gaps.push((seq32, first_id, count));
                        Err(e)
                    }
                }
            }
            Some(first_id) => {
                before_publish(first_id, count, fanout);
                let published =
                    self.complete_groups(&def, events, offs, first_id, tag, &groups)?;
                if published == 0 {
                    self.telemetry.frontend.dedup_hits.incr();
                }
                ps.record_done(seq32, first_id, count);
                Ok(IngestOutcome {
                    first_ingest_id: first_id,
                    count,
                    fanout,
                    duplicate: published == 0,
                })
            }
        }
    }

    /// Recompute a tagged batch's deterministic routing — the same
    /// (entity, partition) groups, in the same in-group order, that
    /// [`FrontEnd::route_raw_batch`] publishes — and scan each group's
    /// partition for records already carrying `tag`. Retry-path only:
    /// the scans are O(partition).
    fn tagged_groups(
        &self,
        def: &StreamDef,
        events: &[RawEvent<'_>],
        offsets: &[u32],
        tag: u64,
    ) -> Result<Vec<TaggedGroup>> {
        let arity = def.schema.len();
        let topics = def.topics();
        let entity_idxs: Vec<usize> = def
            .entities
            .iter()
            .map(|e| def.schema.index_of(e).expect("validated"))
            .collect();
        let partition_counts: Vec<u32> = topics
            .iter()
            .map(|t| {
                self.broker
                    .partition_count(t)
                    .ok_or_else(|| Error::not_found(format!("topic '{t}'")))
            })
            .collect::<Result<_>>()?;
        let mut keyed: Vec<((usize, u32), u32)> =
            Vec::with_capacity(events.len() * entity_idxs.len());
        let mut key_buf: Vec<u8> = Vec::with_capacity(32);
        for (i, re) in events.iter().enumerate() {
            let view = EventView::from_parts(
                re.timestamp,
                re.values,
                &offsets[i * arity..(i + 1) * arity],
                &def.schema,
            );
            for (e_idx, &field_idx) in entity_idxs.iter().enumerate() {
                key_buf.clear();
                view.value_at(field_idx).key_bytes(&mut key_buf);
                let h = hash::hash64(&key_buf);
                let partition = hash::partition_for(h, partition_counts[e_idx]);
                keyed.push(((e_idx, partition), i as u32));
            }
        }
        // stable sort: in-group order = input order, exactly like the
        // publish path's replica sort
        keyed.sort_by_key(|(k, _)| *k);
        let mut groups: Vec<TaggedGroup> = Vec::new();
        for ((e_idx, partition), event) in keyed {
            match groups.last_mut() {
                Some(g) if g.topic == e_idx && g.partition == partition => g.entries.push(event),
                _ => groups.push(TaggedGroup {
                    topic: e_idx,
                    partition,
                    entries: vec![event],
                    durable: 0,
                    earliest: None,
                }),
            }
        }
        for g in &mut groups {
            let (n, earliest) = self.producer.tagged(&topics[g.topic], g.partition, tag)?;
            if n as usize > g.entries.len() {
                return Err(Error::internal(format!(
                    "tag {tag:#x}: partition {}/{} holds {n} records for a {}-entry group",
                    topics[g.topic],
                    g.partition,
                    g.entries.len()
                )));
            }
            g.durable = n;
            g.earliest = earliest;
        }
        Ok(groups)
    }

    /// Publish every group's missing suffix in descending
    /// (entity, partition) order — the same order a fresh publish uses —
    /// re-encoding payloads under the batch's original id range, so the
    /// appended records are byte-identical to what the first attempt
    /// would have written. Returns the number of records appended.
    fn complete_groups(
        &self,
        def: &StreamDef,
        events: &[RawEvent<'_>],
        offsets: &[u32],
        first_id: u64,
        tag: u64,
        groups: &[TaggedGroup],
    ) -> Result<u64> {
        let arity = def.schema.len();
        let topics = def.topics();
        let entity_idxs: Vec<usize> = def
            .entities
            .iter()
            .map(|e| def.schema.index_of(e).expect("validated"))
            .collect();
        let mut published = 0u64;
        let mut key_buf: Vec<u8> = Vec::with_capacity(32);
        for g in groups.iter().rev() {
            crate::failpoint::trigger("frontend.publish_partition")?;
            if g.durable as usize == g.entries.len() {
                continue;
            }
            let missing = &g.entries[g.durable as usize..];
            let field_idx = entity_idxs[g.topic];
            let mut entries: Vec<BatchEntry> = Vec::with_capacity(missing.len());
            for &i in missing {
                let re = &events[i as usize];
                let view = EventView::from_parts(
                    re.timestamp,
                    re.values,
                    &offsets[i as usize * arity..(i as usize + 1) * arity],
                    &def.schema,
                );
                key_buf.clear();
                view.value_at(field_idx).key_bytes(&mut key_buf);
                entries.push(BatchEntry {
                    timestamp: re.timestamp,
                    key: key_buf.as_slice().into(),
                    payload: Envelope::encode_raw(first_id + i as u64, re.timestamp, re.values)
                        .into(),
                    seq: tag,
                });
            }
            self.producer
                .send_batch(&topics[g.topic], g.partition, entries)?;
            published += missing.len() as u64;
        }
        if published > 0 {
            self.telemetry.frontend.dup_suffix_published.add(published);
        }
        Ok(published)
    }

    /// The shared routing tail of every ingest path: splice envelope
    /// payloads, read entity keys through borrowed views (the caller's
    /// validated offset table), intern the keys, group replicas by
    /// (entity, partition) and publish. Callers guarantee `offsets` is a
    /// valid scan of `events` against `def.schema`. `tag` is the
    /// idempotent-producer tag stamped on every record (`0` = untagged —
    /// the in-process paths, whose retries are the caller's problem).
    fn route_raw_batch(
        &self,
        def: &StreamDef,
        events: &[RawEvent<'_>],
        first_id: u64,
        offsets: &[u32],
        tag: u64,
    ) -> Result<Vec<IngestReceipt>> {
        let arity = def.schema.len();
        let fanout = def.entities.len() as u32;
        let entity_idxs: Vec<usize> = def
            .entities
            .iter()
            .map(|e| def.schema.index_of(e).expect("validated"))
            .collect();
        let topics = def.topics();
        let partition_counts: Vec<u32> = topics
            .iter()
            .map(|t| {
                self.broker
                    .partition_count(t)
                    .ok_or_else(|| Error::not_found(format!("topic '{t}'")))
            })
            .collect::<Result<_>>()?;
        // build every replica into one flat vec, then group by
        // (entity, partition) with a stable sort — no per-batch hash map,
        // no per-group vec. Keys are **interned per batch**: each
        // distinct key's bytes become one shared `Arc<[u8]>` (dedup'd by
        // the routing hash we compute anyway, byte-compared on
        // collision), so a hot key appearing thousands of times in a
        // batch allocates once and the producer handoff is an Arc clone
        // — no per-replica key allocation anywhere. Payloads are spliced
        // once per event and shared across its replicas the same way.
        let mut key_buf: Vec<u8> = Vec::with_capacity(32);
        let mut key_arcs: Vec<Payload> = Vec::new();
        let mut interner: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
        let mut payloads: Vec<Payload> = Vec::with_capacity(events.len());
        let mut replicas: Vec<((usize, u32), Replica)> =
            Vec::with_capacity(events.len() * entity_idxs.len());
        let mut receipts = Vec::with_capacity(events.len());
        // telemetry: accumulate locally, flush once per batch (the
        // per-event loop stays free of atomics)
        let mut interner_hits = 0u64;
        let mut interner_misses = 0u64;
        for (i, re) in events.iter().enumerate() {
            let ingest_id = first_id + i as u64;
            payloads.push(Envelope::encode_raw(ingest_id, re.timestamp, re.values).into());
            let view = EventView::from_parts(
                re.timestamp,
                re.values,
                &offsets[i * arity..(i + 1) * arity],
                &def.schema,
            );
            for (e_idx, &field_idx) in entity_idxs.iter().enumerate() {
                key_buf.clear();
                view.value_at(field_idx).key_bytes(&mut key_buf);
                let h = hash::hash64(&key_buf);
                let partition = hash::partition_for(h, partition_counts[e_idx]);
                let candidates = interner.entry(h).or_default();
                let key = match candidates
                    .iter()
                    .copied()
                    .find(|&c| key_arcs[c as usize][..] == key_buf[..])
                {
                    Some(c) => {
                        interner_hits += 1;
                        c
                    }
                    None => {
                        interner_misses += 1;
                        let idx = key_arcs.len() as u32;
                        key_arcs.push(key_buf.as_slice().into());
                        candidates.push(idx);
                        idx
                    }
                };
                replicas.push((
                    (e_idx, partition),
                    Replica {
                        event: i as u32,
                        key,
                    },
                ));
            }
            receipts.push(IngestReceipt { ingest_id, fanout });
        }
        let fstats = &self.telemetry.frontend;
        fstats.batches.incr();
        fstats.events.add(events.len() as u64);
        fstats.interner_hits.add(interner_hits);
        fstats.interner_misses.add(interner_misses);
        // stable sort keeps input order within each (entity, partition)
        // run; one producer append per run, capped at `ingest_batch`
        // records per call. Runs are consumed from the vec's tail, so the
        // group order is deterministic (descending (entity, partition)) —
        // a mid-batch failure leaves a prefix of that ordering durable.
        replicas.sort_by_key(|(k, _)| *k);
        let entry_of = |r: &Replica| BatchEntry {
            timestamp: events[r.event as usize].timestamp,
            key: key_arcs[r.key as usize].clone(),
            payload: payloads[r.event as usize].clone(),
            seq: tag,
        };
        while let Some(key) = replicas.last().map(|(k, _)| *k) {
            crate::failpoint::trigger("frontend.publish_partition")?;
            let (e_idx, partition) = key;
            let topic = &topics[e_idx];
            let run_start = replicas.partition_point(|(k, _)| *k < key);
            // chunks are drained front-to-back within the run so the
            // per-partition record order follows the input order
            while replicas.len() - run_start > self.ingest_batch {
                let chunk_end = run_start + self.ingest_batch;
                self.producer.send_batch(
                    topic,
                    partition,
                    replicas
                        .drain(run_start..chunk_end)
                        .map(|(_, r)| entry_of(&r)),
                )?;
            }
            self.producer.send_batch(
                topic,
                partition,
                replicas.drain(run_start..).map(|(_, r)| entry_of(&r)),
            )?;
        }
        Ok(receipts)
    }

    /// Ingest from client JSON.
    pub fn ingest_json(&self, stream: &str, text: &str) -> Result<IngestReceipt> {
        let def = self.stream(stream)?;
        let event = crate::event::json::event_from_json_str(text, &def.schema)?;
        self.ingest(stream, event)
    }

    /// Create a reply collector (its own consumer group so multiple
    /// collectors are independent). The collector starts at the reply
    /// topic's **end**: it only sees replies to events ingested after its
    /// creation (stale replies from previous runs are skipped).
    pub fn reply_collector(&self, group: &str) -> Result<ReplyCollector> {
        self.broker.ensure_topic(REPLY_TOPIC, self.reply_partitions)?;
        let mut consumer = self.broker.consumer(group, &[REPLY_TOPIC])?;
        // force the initial assignment, then seek to the live end
        let _ = consumer.poll(0, Duration::from_millis(0))?;
        for tp in consumer.assignment().to_vec() {
            let end = self.broker.end_offset(&tp)?;
            consumer.seek(tp, end);
        }
        Ok(ReplyCollector {
            consumer,
            pending: FxHashMap::default(),
        })
    }
}

/// Collects reply messages and reassembles per-event answers.
pub struct ReplyCollector {
    consumer: Consumer,
    /// ingest_id → replies received so far.
    pending: FxHashMap<u64, Vec<ReplyMsg>>,
}

impl ReplyCollector {
    /// Drain available replies into the pending map. Each reply record
    /// may carry a whole batch of messages; returns the number of
    /// messages absorbed.
    pub fn pump(&mut self, timeout: Duration) -> Result<usize> {
        let polled = self.consumer.poll(1024, timeout)?;
        let mut n = 0;
        for (_, rec) in polled.records {
            for msg in ReplyMsg::decode_batch(&rec.payload)? {
                self.pending.entry(msg.ingest_id).or_default().push(msg);
                n += 1;
            }
        }
        Ok(n)
    }

    /// Wait until `expected` replies for `ingest_id` have arrived (step 6
    /// of Figure 2). Returns the replies, removing them from the pending
    /// set.
    pub fn await_event(
        &mut self,
        ingest_id: u64,
        expected: u32,
        timeout: Duration,
    ) -> Result<Vec<ReplyMsg>> {
        let deadline = Instant::now() + timeout;
        loop {
            if self
                .pending
                .get(&ingest_id)
                .map(|v| v.len() >= expected as usize)
                .unwrap_or(false)
            {
                return Ok(self.pending.remove(&ingest_id).unwrap());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::closed(format!(
                    "timed out waiting for {expected} replies to ingest {ingest_id} (have {})",
                    self.pending.get(&ingest_id).map(|v| v.len()).unwrap_or(0)
                )));
            }
            self.pump(deadline - now)?;
        }
    }

    /// Non-blocking: take whatever replies have arrived for an event.
    pub fn take_partial(&mut self, ingest_id: u64) -> Vec<ReplyMsg> {
        self.pending.remove(&ingest_id).unwrap_or_default()
    }

    /// Number of events with outstanding replies.
    pub fn pending_events(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::AggKind;
    use crate::event::Value;
    use crate::mlog::{Broker, BrokerConfig};
    use crate::plan::MetricSpec;
    use crate::window::WindowSpec;
    use crate::workload::payments_schema;

    fn registry() -> Registry {
        Arc::new(RwLock::new(FxHashMap::default()))
    }

    fn def() -> StreamDef {
        StreamDef {
            name: "payments".into(),
            schema: payments_schema(),
            entities: vec!["card".into(), "merchant".into()],
            metrics: vec![
                MetricSpec::new(
                    "sum_by_card",
                    AggKind::Sum,
                    Some("amount"),
                    WindowSpec::sliding(300_000),
                    &["card"],
                ),
                MetricSpec::new(
                    "avg_by_merchant",
                    AggKind::Avg,
                    Some("amount"),
                    WindowSpec::sliding(300_000),
                    &["merchant"],
                ),
            ],
        }
    }

    fn ev(ts: i64, card: &str, merchant: &str, amount: f64) -> Event {
        Event::new(
            ts,
            vec![
                Value::Str(card.into()),
                Value::Str(merchant.into()),
                Value::F64(amount),
                Value::Bool(false),
            ],
        )
    }

    #[test]
    fn envelope_roundtrip() {
        let schema = payments_schema();
        let env = Envelope {
            ingest_id: 42,
            event: ev(1000, "c1", "m1", 9.5),
        };
        let buf = env.encode(&schema);
        assert_eq!(Envelope::decode(&buf, &schema).unwrap(), env);
        assert!(Envelope::decode(&buf[..buf.len() - 1], &schema).is_err());
    }

    fn reply_msg(ingest_id: u64) -> ReplyMsg {
        ReplyMsg {
            ingest_id,
            topic: "payments.card".into(),
            partition: 3,
            event_ts: 123,
            metrics: vec![
                ReplyMetric {
                    name: "sum".into(),
                    group: "c1".into(),
                    value: Some(10.5),
                },
                ReplyMetric {
                    name: "min".into(),
                    group: "c1".into(),
                    value: None,
                },
            ],
        }
    }

    #[test]
    fn reply_binary_roundtrip() {
        let msgs = vec![reply_msg(7), reply_msg(8), reply_msg(9)];
        let buf = ReplyMsg::encode_batch(&msgs);
        assert_eq!(ReplyMsg::decode_batch(&buf).unwrap(), msgs);
        // truncation anywhere inside the last message is detected
        assert!(ReplyMsg::decode_batch(&buf[..buf.len() - 1]).is_err());
        assert!(ReplyMsg::decode_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn reply_json_rendering() {
        let json = reply_msg(7).to_json().to_string();
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.get("ingest_id").and_then(|j| j.as_i64()), Some(7));
        assert_eq!(
            parsed.get("metrics").and_then(|j| j.as_arr()).map(|a| a.len()),
            Some(2)
        );
    }

    #[test]
    fn register_creates_topics() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        assert_eq!(broker.partition_count("payments.card"), Some(4));
        assert_eq!(broker.partition_count("payments.merchant"), Some(4));
        assert_eq!(broker.partition_count(REPLY_TOPIC), Some(1));
        assert!(fe.register_stream(def()).is_err(), "duplicate stream");
    }

    #[test]
    fn reply_topic_sharding_and_routing() {
        assert_eq!(reply_partition_for(0, 4), 0);
        assert_eq!(reply_partition_for(7, 4), 3);
        assert_eq!(reply_partition_for(7, 1), 0);
        assert_eq!(reply_partition_for(7, 0), 0);
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2).with_reply_partitions(4);
        fe.register_stream(def()).unwrap();
        assert_eq!(broker.partition_count(REPLY_TOPIC), Some(4));
        // a collector subscribes every shard and still assembles replies
        let mut rc = fe.reply_collector("sharded").unwrap();
        let producer = broker.producer();
        for id in 0..8u64 {
            let msg = ReplyMsg {
                ingest_id: id,
                topic: "payments.card".into(),
                partition: 0,
                event_ts: 1,
                metrics: vec![],
            };
            producer
                .send(
                    REPLY_TOPIC,
                    reply_partition_for(id, 4),
                    1,
                    vec![],
                    ReplyMsg::encode_batch(&[msg]),
                )
                .unwrap();
        }
        for id in 0..8u64 {
            let replies = rc.await_event(id, 1, Duration::from_secs(5)).unwrap();
            assert_eq!(replies.len(), 1);
        }
    }

    #[test]
    fn ingest_replicates_to_entity_topics_keyed_consistently() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        let r1 = fe.ingest("payments", ev(1, "c1", "m1", 5.0)).unwrap();
        assert_eq!(r1.fanout, 2);
        let r2 = fe.ingest("payments", ev(2, "c1", "m2", 6.0)).unwrap();
        assert!(r2.ingest_id > r1.ingest_id);
        // same card ⇒ same partition of the card topic
        let mut c = broker.consumer("g", &["payments.card"]).unwrap();
        let mut partitions = std::collections::HashSet::new();
        loop {
            let p = c.poll(100, Duration::from_millis(10)).unwrap();
            if p.records.is_empty() && p.rebalanced.is_none() {
                break;
            }
            for (tp, rec) in p.records {
                partitions.insert(tp.partition);
                // envelope decodes with the schema
                let env = Envelope::decode(&rec.payload, &payments_schema()).unwrap();
                assert_eq!(env.event.values[0].as_str(), Some("c1"));
            }
        }
        assert_eq!(partitions.len(), 1);
    }

    #[test]
    fn ingest_validates_schema() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker, registry(), 2);
        fe.register_stream(def()).unwrap();
        let bad = Event::new(0, vec![Value::I64(1)]);
        assert!(fe.ingest("payments", bad).is_err());
        assert!(fe.ingest("nope", ev(0, "c", "m", 1.0)).is_err());
    }

    #[test]
    fn ingest_json_end_to_end() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker, registry(), 2);
        fe.register_stream(def()).unwrap();
        let r = fe
            .ingest_json(
                "payments",
                r#"{"timestamp": 5, "card": "c9", "merchant": "m3", "amount": 12.5}"#,
            )
            .unwrap();
        assert_eq!(r.fanout, 2);
        assert!(fe.ingest_json("payments", r#"{"card": "c9"}"#).is_err());
    }

    #[test]
    fn ingest_batch_matches_per_event_routing() {
        // the same events through ingest() and ingest_batch() must land
        // in the same partitions, in the same per-partition order, with
        // identical envelope payload bytes
        let events: Vec<Event> = (0..40)
            .map(|i| ev(i, &format!("c{}", i % 5), &format!("m{}", i % 3), i as f64))
            .collect();
        let drain = |broker: &crate::mlog::BrokerRef| {
            let mut out: Vec<(String, u32, Vec<u8>)> = Vec::new();
            for topic in ["payments.card", "payments.merchant"] {
                let mut c = broker.consumer(&format!("drain-{topic}"), &[topic]).unwrap();
                loop {
                    let p = c.poll(1000, Duration::from_millis(10)).unwrap();
                    if p.records.is_empty() && p.rebalanced.is_none() {
                        break;
                    }
                    for (tp, rec) in p.records {
                        // strip the ingest-id prefix: ids differ per front-end
                        let mut pos = 0;
                        varint::read_u64(&rec.payload, &mut pos).unwrap();
                        out.push((tp.topic, tp.partition, rec.payload[pos..].to_vec()));
                    }
                }
            }
            out
        };

        let broker_a = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe_a = FrontEnd::new(broker_a.clone(), registry(), 4);
        fe_a.register_stream(def()).unwrap();
        for e in &events {
            fe_a.ingest("payments", e.clone()).unwrap();
        }

        let broker_b = Broker::open(BrokerConfig::in_memory()).unwrap();
        // tiny ingest_batch cap to exercise the chunked append path
        let fe_b = FrontEnd::new(broker_b.clone(), registry(), 4).with_ingest_batch(7);
        fe_b.register_stream(def()).unwrap();
        let receipts = fe_b.ingest_batch("payments", events.clone()).unwrap();
        assert_eq!(receipts.len(), events.len());
        for w in receipts.windows(2) {
            assert_eq!(w[1].ingest_id, w[0].ingest_id + 1);
        }
        assert!(receipts.iter().all(|r| r.fanout == 2));

        assert_eq!(drain(&broker_a), drain(&broker_b));
        assert!(fe_b.ingest_batch("payments", Vec::new()).unwrap().is_empty());
    }

    /// Encode owned events into one scratch buffer + [`RawEvent`] spans
    /// (what a raw-path caller holds).
    fn encode_raws(events: &[Event]) -> (Vec<u8>, Vec<(i64, usize, usize)>) {
        let schema = payments_schema();
        let mut buf = Vec::new();
        let mut spans = Vec::new();
        for e in events {
            let start = buf.len();
            codec::encode_values_into(&mut buf, e, &schema);
            spans.push((e.timestamp, start, buf.len()));
        }
        (buf, spans)
    }

    #[test]
    fn ingest_batch_raw_matches_owned_batch_bytes() {
        // the same events through the owned and raw batch paths must
        // produce identical records: topic, partition, key bytes and
        // payload bytes (ingest ids normalized away)
        let events: Vec<Event> = (0..40)
            .map(|i| ev(i, &format!("c{}", i % 5), &format!("m{}", i % 3), i as f64))
            .collect();
        let drain = |broker: &crate::mlog::BrokerRef| {
            let mut out: Vec<(String, u32, Vec<u8>, Vec<u8>)> = Vec::new();
            for topic in ["payments.card", "payments.merchant"] {
                let mut c = broker.consumer(&format!("drain-{topic}"), &[topic]).unwrap();
                loop {
                    let p = c.poll(1000, Duration::from_millis(10)).unwrap();
                    if p.records.is_empty() && p.rebalanced.is_none() {
                        break;
                    }
                    for (tp, rec) in p.records {
                        // strip the ingest-id prefix: ids differ per front-end
                        let mut pos = 0;
                        varint::read_u64(&rec.payload, &mut pos).unwrap();
                        out.push((
                            tp.topic,
                            tp.partition,
                            rec.key.to_vec(),
                            rec.payload[pos..].to_vec(),
                        ));
                    }
                }
            }
            out
        };

        let broker_a = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe_a = FrontEnd::new(broker_a.clone(), registry(), 4).with_ingest_batch(7);
        fe_a.register_stream(def()).unwrap();
        fe_a.ingest_batch("payments", events.clone()).unwrap();

        let broker_b = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe_b = FrontEnd::new(broker_b.clone(), registry(), 4).with_ingest_batch(7);
        fe_b.register_stream(def()).unwrap();
        let schema = payments_schema();
        let mut batch = RawBatchBuf::new();
        for e in &events {
            batch.push(e, &schema);
        }
        let receipts = fe_b.ingest_batch_raw("payments", &batch.raws()).unwrap();
        assert_eq!(receipts.len(), events.len());
        for w in receipts.windows(2) {
            assert_eq!(w[1].ingest_id, w[0].ingest_id + 1);
        }
        assert!(receipts.iter().all(|r| r.fanout == 2));

        assert_eq!(drain(&broker_a), drain(&broker_b));
        assert!(fe_b.ingest_batch_raw("payments", &[]).unwrap().is_empty());
    }

    #[test]
    fn ingest_batch_raw_validates_all_events_upfront() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2);
        fe.register_stream(def()).unwrap();
        let good = ev(1, "c1", "m1", 5.0);
        let (buf, spans) = encode_raws(std::slice::from_ref(&good));
        let (ts, s, e) = spans[0];
        // garbage value bytes: rejected
        let garbage = [0x07u8, 0xff, 0xff];
        let batch = [
            RawEvent {
                timestamp: ts,
                values: &buf[s..e],
            },
            RawEvent {
                timestamp: 2,
                values: &garbage,
            },
        ];
        assert!(fe.ingest_batch_raw("payments", &batch).is_err());
        // a truncated value section is rejected too
        let truncated = [RawEvent {
            timestamp: ts,
            values: &buf[s..e - 1],
        }];
        assert!(fe.ingest_batch_raw("payments", &truncated).is_err());
        // trailing bytes after a valid section are rejected
        let mut padded = buf[s..e].to_vec();
        padded.push(0);
        let trailing = [RawEvent {
            timestamp: ts,
            values: &padded,
        }];
        assert!(fe.ingest_batch_raw("payments", &trailing).is_err());
        // nothing was published: the batch is validated before routing
        let mut c = broker.consumer("g", &["payments.card"]).unwrap();
        let p = c.poll(10, Duration::from_millis(10)).unwrap();
        assert!(p.records.is_empty());
        // envelope splice is byte-identical to the owned encoder
        let env = Envelope {
            ingest_id: 42,
            event: good.clone(),
        };
        assert_eq!(
            env.encode(&payments_schema()),
            Envelope::encode_raw(42, ts, &buf[s..e])
        );
    }

    #[test]
    fn ingest_batch_validates_all_events_upfront() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2);
        fe.register_stream(def()).unwrap();
        let bad = vec![ev(1, "c1", "m1", 5.0), Event::new(0, vec![Value::I64(1)])];
        assert!(fe.ingest_batch("payments", bad).is_err());
        // nothing was published: the batch is validated before routing
        let mut c = broker.consumer("g", &["payments.card"]).unwrap();
        let p = c.poll(10, Duration::from_millis(10)).unwrap();
        assert!(p.records.is_empty());
    }

    #[test]
    fn reply_collector_assembles() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2);
        fe.register_stream(def()).unwrap();
        let mut rc = fe.reply_collector("collector").unwrap();
        // simulate two task processors replying for ingest 5; one of them
        // batches its reply with a message for ingest 6
        let producer = broker.producer();
        let batches: [Vec<ReplyMsg>; 2] = [
            vec![ReplyMsg {
                ingest_id: 5,
                topic: "payments.card".into(),
                partition: 0,
                event_ts: 1,
                metrics: vec![],
            }],
            vec![
                ReplyMsg {
                    ingest_id: 5,
                    topic: "payments.merchant".into(),
                    partition: 1,
                    event_ts: 1,
                    metrics: vec![],
                },
                ReplyMsg {
                    ingest_id: 6,
                    topic: "payments.merchant".into(),
                    partition: 1,
                    event_ts: 2,
                    metrics: vec![],
                },
            ],
        ];
        for batch in &batches {
            producer
                .send(REPLY_TOPIC, 0, 1, vec![], ReplyMsg::encode_batch(batch))
                .unwrap();
        }
        let replies = rc.await_event(5, 2, Duration::from_secs(5)).unwrap();
        assert_eq!(replies.len(), 2);
        let replies = rc.await_event(6, 1, Duration::from_secs(5)).unwrap();
        assert_eq!(replies.len(), 1);
        assert_eq!(rc.pending_events(), 0);
        // timeout on missing event
        assert!(rc.await_event(99, 1, Duration::from_millis(30)).is_err());
    }

    /// Drain every record of the stream's entity topics:
    /// (topic, partition, seq tag, key bytes, payload with the ingest-id
    /// varint stripped — ids differ per front-end instance).
    fn drain_tagged(broker: &crate::mlog::BrokerRef) -> Vec<(String, u32, u64, Vec<u8>, Vec<u8>)> {
        let mut out = Vec::new();
        for topic in ["payments.card", "payments.merchant"] {
            let mut c = broker.consumer(&format!("drain-{topic}"), &[topic]).unwrap();
            loop {
                let p = c.poll(1000, Duration::from_millis(10)).unwrap();
                if p.records.is_empty() && p.rebalanced.is_none() {
                    break;
                }
                for (tp, rec) in p.records {
                    let mut pos = 0;
                    varint::read_u64(&rec.payload, &mut pos).unwrap();
                    out.push((
                        tp.topic,
                        tp.partition,
                        rec.seq,
                        rec.key.to_vec(),
                        rec.payload[pos..].to_vec(),
                    ));
                }
            }
        }
        out
    }

    #[test]
    fn tagged_ingest_dedups_exact_duplicate() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        let (pid, epoch) = fe.register_producer(0, 0);
        assert_eq!(epoch, 1);
        let events: Vec<Event> = (0..20)
            .map(|i| ev(i, &format!("c{}", i % 5), &format!("m{}", i % 3), i as f64))
            .collect();
        let schema = payments_schema();
        let mut batch = RawBatchBuf::new();
        for e in &events {
            batch.push(e, &schema);
        }
        let mut callbacks: Vec<(u64, u32, u32)> = Vec::new();
        let out1 = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |f, c, fo| {
                callbacks.push((f, c, fo))
            })
            .unwrap();
        assert!(!out1.duplicate);
        assert_eq!(out1.count, 20);
        assert_eq!(out1.fanout, 2);
        // exact resend: acked as duplicate with the original id range,
        // before_publish still runs (the server re-registers replies)
        let out2 = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |f, c, fo| {
                callbacks.push((f, c, fo))
            })
            .unwrap();
        assert!(out2.duplicate);
        assert_eq!(out2.first_ingest_id, out1.first_ingest_id);
        assert_eq!((out2.count, out2.fanout), (out1.count, out1.fanout));
        assert_eq!(callbacks.len(), 2);
        assert_eq!(callbacks[0], callbacks[1]);
        assert_eq!(fe.telemetry().frontend.dedup_hits.get(), 1);
        // nothing was re-appended, and every record carries the tag
        let records = drain_tagged(&broker);
        assert_eq!(records.len(), events.len() * 2);
        let tag = (pid as u64) << 32 | 1;
        assert!(records.iter().all(|r| r.2 == tag));
        // the next seq is fresh again and ids advance
        let out3 = fe
            .ingest_batch_raw_tagged("payments", pid, 2, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();
        assert!(!out3.duplicate);
        assert!(out3.first_ingest_id > out1.first_ingest_id);
        // a "duplicate" with a different event count is rejected
        let short = &batch.raws()[..10];
        assert!(fe
            .ingest_batch_raw_tagged("payments", pid, 1, short, None, &mut |_, _, _| {})
            .is_err());
        // unregistered identities and seq 0 are rejected
        assert!(fe
            .ingest_batch_raw_tagged("payments", 0, 1, &batch.raws(), None, &mut |_, _, _| {})
            .is_err());
        assert!(fe
            .ingest_batch_raw_tagged("payments", pid, 0, &batch.raws(), None, &mut |_, _, _| {})
            .is_err());
    }

    #[test]
    fn tagged_resume_after_restart_dedups_from_record_tags() {
        let tmp = crate::util::tmp::TempDir::new("fe_tagged_restart");
        let events: Vec<Event> = (0..20)
            .map(|i| ev(i, &format!("c{}", i % 5), &format!("m{}", i % 3), i as f64))
            .collect();
        let schema = payments_schema();
        let mut batch = RawBatchBuf::new();
        for e in &events {
            batch.push(e, &schema);
        }
        let (pid, out1) = {
            let broker =
                Broker::open(BrokerConfig::durable(tmp.path().to_path_buf())).unwrap();
            let fe = FrontEnd::new(broker.clone(), registry(), 2);
            fe.register_stream(def()).unwrap();
            let (pid, _) = fe.register_producer(0, 0);
            let out = fe
                .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |_, _, _| {})
                .unwrap();
            broker.sync_all().unwrap();
            (pid, out)
        };
        // restart: a fresh broker + front-end over the same directory
        let broker = Broker::open(BrokerConfig::durable(tmp.path().to_path_buf())).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2);
        fe.register_stream(def()).unwrap();
        // the client resumes its identity; the server must not re-issue it
        let (rpid, _) = fe.register_producer(pid, 1);
        assert_eq!(rpid, pid);
        // the resent batch is below the recovered high water with no
        // in-memory completion record: the durable tags answer, and the
        // ack carries the original id range
        let out2 = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();
        assert!(out2.duplicate);
        assert_eq!(out2.first_ingest_id, out1.first_ingest_id);
        assert_eq!(fe.telemetry().frontend.dedup_hits.get(), 1);
        // no extra records were appended by the resend
        let records = drain_tagged(&broker);
        assert_eq!(records.len(), events.len() * 2);
        // a fresh registration never collides with the recovered identity
        let (fresh, _) = fe.register_producer(0, 0);
        assert!(fresh > pid);
    }

    #[test]
    fn dedup_cap_evicts_idle_and_reseeds_from_tags() {
        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 2).with_dedup_producer_cap(2);
        fe.register_stream(def()).unwrap();
        let events: Vec<Event> = (0..4).map(|i| ev(i, "c1", "m1", i as f64)).collect();
        let schema = payments_schema();
        let mut batch = RawBatchBuf::new();
        for e in &events {
            batch.push(e, &schema);
        }
        let (p1, _) = fe.register_producer(0, 0);
        let out1 = fe
            .ingest_batch_raw_tagged("payments", p1, 1, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();
        // later registrations push the table past the cap; the idle p1
        // is the eviction victim
        std::thread::sleep(Duration::from_millis(2));
        let (p2, _) = fe.register_producer(0, 0);
        std::thread::sleep(Duration::from_millis(2));
        let (p3, _) = fe.register_producer(0, 0);
        assert_ne!((p2, p3), (p1, p1));
        assert_eq!(fe.telemetry().frontend.dedup_evicted.get(), 1);
        // p1 returns and resends its batch: the recreated entry re-seeds
        // from the durable record tags, so the resend still classifies
        // as a duplicate and acks the original id range
        let out2 = fe
            .ingest_batch_raw_tagged("payments", p1, 1, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();
        assert!(out2.duplicate, "eviction must not weaken exactly-once");
        assert_eq!(out2.first_ingest_id, out1.first_ingest_id);
        // nothing was re-appended across the eviction + resend
        let records = drain_tagged(&broker);
        assert_eq!(records.len(), events.len() * 2, "fanout 2, no rewrites");
        // cap 0 = unbounded: no eviction however many producers register
        let fe2 = FrontEnd::new(broker.clone(), registry(), 2).with_dedup_producer_cap(0);
        for _ in 0..10 {
            fe2.register_producer(0, 0);
        }
        assert_eq!(fe2.telemetry().frontend.dedup_evicted.get(), 0);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn tagged_retry_completes_missing_suffix_byte_identically() {
        // control: the same batch published with no fault
        let control_broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let control_fe = FrontEnd::new(control_broker.clone(), registry(), 4);
        control_fe.register_stream(def()).unwrap();
        let (cpid, _) = control_fe.register_producer(0, 0);
        let events: Vec<Event> = (0..40)
            .map(|i| ev(i, &format!("c{}", i % 5), &format!("m{}", i % 3), i as f64))
            .collect();
        let schema = payments_schema();
        let mut batch = RawBatchBuf::new();
        for e in &events {
            batch.push(e, &schema);
        }
        control_fe
            .ingest_batch_raw_tagged("payments", cpid, 1, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();

        let broker = Broker::open(BrokerConfig::in_memory()).unwrap();
        let fe = FrontEnd::new(broker.clone(), registry(), 4);
        fe.register_stream(def()).unwrap();
        let (pid, _) = fe.register_producer(0, 0);
        assert_eq!(pid, cpid, "both front-ends mint the same first id");
        // fail the second partition-group append: the first group lands,
        // the rest of the batch never publishes
        crate::failpoint::arm("frontend.publish_partition", crate::failpoint::Action::Fail {
            at: 2,
        });
        let mut first_ids: Vec<u64> = Vec::new();
        let err = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |f, _, _| {
                first_ids.push(f)
            })
            .unwrap_err();
        assert!(err.to_string().contains("failpoint"), "{err}");
        let partial = drain_tagged(&broker).len();
        assert!(partial > 0, "first group must be durable");
        assert!(partial < events.len() * 2, "the fault left a gap");
        // the retry (failpoint disarmed itself) completes the suffix
        // under the original ids
        let out = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |f, _, _| {
                first_ids.push(f)
            })
            .unwrap();
        assert!(!out.duplicate, "records were appended on the retry");
        assert_eq!(first_ids.len(), 2);
        assert_eq!(first_ids[0], first_ids[1], "retry keeps the id range");
        assert_eq!(out.first_ingest_id, first_ids[0]);
        assert!(fe.telemetry().frontend.dup_suffix_published.get() > 0);
        // …and the final log is byte-identical to the un-faulted control
        // (drain_tagged strips ingest ids, which differ per front-end;
        // both brokers were drained from offset 0 so order is total)
        let mut faulted = drain_tagged(&broker);
        let mut control = drain_tagged(&control_broker);
        faulted.sort();
        control.sort();
        assert_eq!(faulted, control);
        // a third send is a pure duplicate
        let out3 = fe
            .ingest_batch_raw_tagged("payments", pid, 1, &batch.raws(), None, &mut |_, _, _| {})
            .unwrap();
        assert!(out3.duplicate);
    }
}
