//! # Railgun — real-time sliding windows for mission critical systems
//!
//! Reproduction of *"Railgun: streaming windows for mission critical
//! systems"* (Oliveirinha, Gomes, Cardoso, Bizarro — Feedzai, CIDR '21).
//!
//! Railgun is a distributed streaming engine that computes **accurate,
//! per-event aggregations over real sliding windows** at millisecond
//! latencies. Unlike Type-2 engines (Flink, Kafka Streams, Spark
//! Streaming) that approximate sliding windows with a fixed set of
//! overlapping *hopping* windows, Railgun evaluates every window on every
//! event arrival, backed by a low-memory-footprint, disk-backed **event
//! reservoir**.
//!
//! ## Architecture (three layers)
//!
//! * **L3 — this crate**: the coordination/storage system. Messaging
//!   ([`mlog`]), front-end routing ([`frontend`]), back-end processor
//!   units ([`backend`]), the event reservoir ([`reservoir`]), operator
//!   plans ([`plan`]), aggregation state ([`agg`], [`kvstore`]), the
//!   cluster coordinator ([`coordinator`]) and the client/server
//!   boundary ([`net`]).
//! * **L2 — JAX** (`python/compile/model.py`, build-time only): batched
//!   aggregation-state transition and the fraud-scoring MLP, lowered
//!   ahead-of-time to HLO text artifacts.
//! * **L1 — Pallas** (`python/compile/kernels/`): the numeric hot-spot
//!   kernels called by L2, validated against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (`xla`
//! crate) and executes them from the rust hot path; python never runs at
//! request time. The PJRT layer is behind the non-default `pjrt` cargo
//! feature — the default build is pure Rust.
//!
//! ## The net layer
//!
//! [`net`] turns the node into an actually-distributed server: a
//! length-prefixed, CRC-checked binary TCP protocol (versioned frames
//! over the same varint event/reply codecs the engine uses internally),
//! an **event-loop** server, and a blocking, pipelining client. The
//! server runs N event-loop workers (default one per core, see
//! [`config::EngineConfig::net_event_workers`]), each driving an epoll
//! instance ([`net::poll`], raw syscall FFI — no async runtime) over a
//! disjoint slice of nonblocking connections, so connection count is
//! decoupled from thread count. Protocol v2 carries ingest batches as
//! **pre-encoded value bytes**: the client encodes each event once, the
//! server's wire decode validates the slices in place — keeping the
//! scan's offset table — and forwards both to the front-end's tagged
//! ingest entry, so each payload is walked exactly once between socket
//! and mlog; the bytes a client encodes are the bytes the reservoir
//! stores, with no owned event anywhere in between. Ingest is
//! **exactly-once under retry**: HELLO negotiates a producer identity,
//! batches carry per-producer sequence numbers persisted as record
//! tags, and a resend after any failure republishes only what never
//! became durable (see [`frontend::FrontEnd::ingest_batch_raw_tagged`]).
//! Replies flow back per connection: the reply topic is **sharded**
//! ([`config::EngineConfig::reply_partitions`]), task processors route
//! each reply record by ingest id ([`frontend::reply_partition_for`]),
//! and the server runs one reply pump per shard, each appending encoded
//! reply frames to the owning connection's bounded outbound queue and
//! waking its worker — pumps never touch sockets, workers flush with
//! vectored writes under a per-connection write budget, and a slow
//! client backpressures only itself. The paper-central numbers — end-to-end ingest→reply
//! latency percentiles under load — are measured from outside the
//! engine by the [`net::bench`] harness (`railgun bench-client`),
//! closed-loop by default or open-loop at a fixed arrival rate with
//! coordinated-omission-corrected latencies (`--rate`).
//!
//! ## Telemetry
//!
//! Every node carries a [`telemetry::Telemetry`] registry: sharded,
//! cache-line-padded atomic counters and log-linear latency histograms
//! covering each stage (net workers, front-end routing, mlog io,
//! backend plan evaluation, reservoir, state store). Hot-path recording
//! is a single relaxed atomic add — never a lock or allocation — and
//! per-worker cells are folded only at **scrape time**. Scrapes are
//! exposed three ways: the `STATS` wire frame (poll any serving node:
//! `railgun stats <addr>`), the `serve --stats-interval <secs>`
//! periodic one-line dump, and `bench-client --stats`, which prints the
//! server-side stage breakdown next to the external latency
//! percentiles so inside and outside views line up in one run.
//!
//! ## Unsafe code
//!
//! Outside the raw epoll/eventfd syscall bindings in [`net::poll`], the
//! crate contains exactly one `unsafe` block — the first on the data
//! path: [`event::EventView::value_at`] skips re-running UTF-8
//! validation on `Str` field access (`from_utf8_unchecked`), justified
//! by the ingest-time invariant that view offsets exist only for
//! buffers `codec::scan_values` already validated — including UTF-8 —
//! and guarded by a `debug_assert`.
//!
//! ## Recovery contract
//!
//! A restarted task processor must converge on the same state, and
//! re-publish the same replies, as a process that never died. Two paths
//! get it there:
//!
//! * **Full replay (the default, `checkpoint_interval = 0`)**: the
//!   reservoir recovers its sealed chunks, then the processor replays
//!   the mlog tail from the last durable record — bounded by the widest
//!   window (only events a window can still contain are re-evaluated).
//! * **Snapshot + tail replay (`EngineConfig::checkpoint_interval` /
//!   `serve --checkpoint-secs`)**: each backend unit periodically
//!   writes a [`checkpoint::Snapshot`] — group interner, aggregate
//!   states, window positions, evaluation clock, processed-record
//!   count, producer dedup high-water — via [`checkpoint::CheckpointStore`]
//!   (temp + fsync + rename, CRC'd, versioned, newest
//!   [`checkpoint::RETAIN`] kept). Recovery loads the newest snapshot
//!   that is *valid*: magic/version/CRC pass, topic and partition
//!   match, its `processed` does not exceed the recovered reservoir
//!   length, and its positions cover every current window offset. State
//!   is restored, the tail `[processed, reservoir end)` is replayed
//!   silently, and the mlog consumer seeks to the reservoir end exactly
//!   as full replay would. An invalid snapshot (torn write, bit flip,
//!   crash mid-checkpoint, config drift) falls back to the next-older
//!   snapshot, then to full replay — never wrong state.
//!
//! **Not checkpointed**: mlog contents, reservoir chunks (both have
//! their own durability), reply routing, and the broker-side producer
//! dedup table (rebuilt from record seq tags; the snapshot's high-water
//! list documents coverage). Snapshots never touch the ingest path —
//! chunk files and reply bytes are byte-identical with checkpointing on
//! or off (`rust/tests/checkpoint_recovery.rs` proves it across clean
//! restarts, an abort mid-checkpoint-write, and a corrupted-latest
//! snapshot).
//!
//! ## Quickstart
//!
//! See `examples/quickstart.rs`. In short: build a [`config::EngineConfig`],
//! start a [`coordinator::Node`], register a stream and its metrics, feed
//! events through the [`frontend::FrontEnd`] and read replies.
//!
//! Over the network (see `examples/net_demo.rs`):
//!
//! ```text
//! # terminal 1 — a serving node (prints "LISTEN 127.0.0.1:<port>")
//! railgun serve --config engine.json --stream stream.json --listen 127.0.0.1:0
//!
//! # terminal 2 — closed-loop latency/throughput from a second process
//! railgun bench-client --addr 127.0.0.1:<port> --stream payments \
//!     --events 200000 --batch 256 --pipeline 8
//! ```

pub mod agg;
pub mod backend;
pub mod baseline;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod event;
pub mod failpoint;
pub mod frontend;
pub mod kvstore;
pub mod mlog;
pub mod net;
pub mod plan;
pub mod reservoir;
pub mod runtime;
pub mod telemetry;
pub mod util;
pub mod window;
pub mod workload;

pub use error::{Error, Result};

/// Crate version string (from Cargo metadata).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
