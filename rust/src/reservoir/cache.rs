//! Chunk cache with LRU eviction and hit/miss/prefetch accounting.
//!
//! The paper's Figure 6 (bottom) hinges on this component: latency stays
//! flat while every iterator's next chunk fits in cache, and degrades as
//! the iterator count approaches the cache capacity (their run: 220 cache
//! elements, knee at ~240 iterators). The counters exported here are what
//! the fig6 bench reports.

use crate::reservoir::chunk::DecodedChunk;
use crate::util::hash::FxHashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Cache statistics (atomic: shared with the prefetch thread).
#[derive(Debug, Default)]
pub struct CacheStats {
    /// Iterator chunk requests served from cache.
    pub hits: AtomicU64,
    /// Iterator chunk requests that had to read the file synchronously —
    /// I/O on the critical path, exactly what eager caching is meant to
    /// prevent.
    pub misses: AtomicU64,
    /// Prefetch requests issued.
    pub prefetch_issued: AtomicU64,
    /// Prefetch loads completed (includes already-cached no-ops).
    pub prefetch_done: AtomicU64,
    /// Chunks evicted by LRU pressure.
    pub evictions: AtomicU64,
}

impl CacheStats {
    /// Hit rate over iterator requests.
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            1.0
        } else {
            h / (h + m)
        }
    }

    /// (hits, misses, prefetch_issued, prefetch_done, evictions) snapshot.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.prefetch_issued.load(Ordering::Relaxed),
            self.prefetch_done.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
        )
    }
}

/// LRU map of chunk_id → decoded chunk.
///
/// Eviction only drops the cache's reference: iterators hold their own
/// `Arc<DecodedChunk>`, so an in-use chunk's memory is released when the
/// last iterator moves off it (the paper's "each iterator requires one
/// chunk in-memory" accounting).
#[derive(Debug)]
pub struct ChunkCache {
    map: FxHashMap<u64, Arc<DecodedChunk>>,
    /// LRU order: front = oldest. Touched ids get pushed to the back;
    /// stale duplicates in the queue are skipped on eviction.
    order: VecDeque<u64>,
    capacity: usize,
    stats: Arc<CacheStats>,
}

impl ChunkCache {
    /// Cache holding at most `capacity` chunks.
    pub fn new(capacity: usize, stats: Arc<CacheStats>) -> Self {
        ChunkCache {
            map: FxHashMap::default(),
            order: VecDeque::with_capacity(capacity * 2),
            capacity: capacity.max(1),
            stats,
        }
    }

    /// Lookup without stats accounting (prefetcher dedup check).
    pub fn peek(&self, chunk_id: u64) -> Option<Arc<DecodedChunk>> {
        self.map.get(&chunk_id).cloned()
    }

    /// Lookup from an iterator: counts hit/miss.
    pub fn get(&mut self, chunk_id: u64) -> Option<Arc<DecodedChunk>> {
        match self.map.get(&chunk_id).cloned() {
            Some(c) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.touch(chunk_id);
                Some(c)
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a chunk (from seal, sync load, or prefetch).
    pub fn insert(&mut self, chunk: Arc<DecodedChunk>) {
        let id = chunk.chunk_id;
        if self.map.insert(id, chunk).is_none() {
            self.order.push_back(id);
            self.evict_if_needed();
        } else {
            self.touch(id);
        }
    }

    fn touch(&mut self, chunk_id: u64) {
        // lazy LRU: append; stale entries are skipped during eviction.
        self.order.push_back(chunk_id);
        // bound the queue so it can't grow unboundedly under heavy touching
        if self.order.len() > self.capacity * 8 {
            self.compact_order();
        }
    }

    fn compact_order(&mut self) {
        let mut seen = crate::util::hash::FxHashSet::default();
        let mut fresh = VecDeque::with_capacity(self.map.len());
        // iterate from back (most recent) keeping first occurrence
        for &id in self.order.iter().rev() {
            if self.map.contains_key(&id) && seen.insert(id) {
                fresh.push_front(id);
            }
        }
        self.order = fresh;
    }

    fn evict_if_needed(&mut self) {
        while self.map.len() > self.capacity {
            match self.order.pop_front() {
                Some(id) => {
                    // skip stale queue entries (already evicted or touched
                    // later — i.e. id appears again later in the queue)
                    let last_pos_is_front = !self.order.contains(&id);
                    if last_pos_is_front && self.map.remove(&id).is_some() {
                        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
    }

    /// Number of cached chunks.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Capacity in chunks.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{Event, Schema};

    fn chunk(id: u64) -> Arc<DecodedChunk> {
        let schema = Schema::of(&[]).unwrap();
        let events = vec![Event::new(id as i64, vec![])];
        Arc::new(DecodedChunk::from_events(id, id * 10, &events, &schema).unwrap())
    }

    fn cache(cap: usize) -> (ChunkCache, Arc<CacheStats>) {
        let stats = Arc::new(CacheStats::default());
        (ChunkCache::new(cap, stats.clone()), stats)
    }

    #[test]
    fn insert_get_hit_miss_counting() {
        let (mut c, stats) = cache(4);
        c.insert(chunk(1));
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
        let (h, m, ..) = stats.snapshot();
        assert_eq!((h, m), (1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn lru_evicts_oldest() {
        let (mut c, stats) = cache(3);
        for id in 0..3 {
            c.insert(chunk(id));
        }
        // touch 0 so it's most-recent
        assert!(c.get(0).is_some());
        c.insert(chunk(3)); // evicts 1 (oldest untouched)
        assert_eq!(c.len(), 3);
        assert!(c.peek(1).is_none(), "1 evicted");
        assert!(c.peek(0).is_some(), "0 survived (touched)");
        assert!(c.peek(2).is_some());
        assert!(c.peek(3).is_some());
        assert_eq!(stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn reinsert_does_not_duplicate() {
        let (mut c, _) = cache(2);
        c.insert(chunk(1));
        c.insert(chunk(1));
        c.insert(chunk(2));
        assert_eq!(c.len(), 2);
        assert!(c.peek(1).is_some());
    }

    #[test]
    fn heavy_touching_stays_bounded() {
        let (mut c, _) = cache(4);
        for id in 0..4 {
            c.insert(chunk(id));
        }
        for _ in 0..10_000 {
            let _ = c.get(2);
        }
        assert!(c.order.len() <= 4 * 8 + 1, "order queue bounded");
        c.insert(chunk(99));
        assert!(c.peek(2).is_some(), "hot chunk survives");
    }

    #[test]
    fn capacity_one() {
        let (mut c, _) = cache(1);
        c.insert(chunk(1));
        c.insert(chunk(2));
        assert_eq!(c.len(), 1);
        assert!(c.peek(2).is_some());
    }

    #[test]
    fn peek_does_not_count() {
        let (mut c, stats) = cache(2);
        c.insert(chunk(1));
        let _ = c.peek(1);
        let _ = c.peek(9);
        let (h, m, ..) = stats.snapshot();
        assert_eq!((h, m), (0, 0));
        let _ = c.get(1);
        assert_eq!(stats.snapshot().0, 1);
    }
}
