//! Chunk format: a fixed-count group of contiguous events, serialized,
//! compressed and persisted as one immutable file.
//!
//! File layout (little-endian):
//!
//! ```text
//! header  := magic:u32 chunk_id:u64 base_seq:u64 count:u32 codec:u8
//!            first_ts:i64 raw_len:u32
//! payload := codec(raw)          raw := event* (the event codec with
//!                                               base_ts = first_ts)
//! trailer := crc32(payload):u32
//! ```
//!
//! Every sealed chunk holds exactly `chunk_events` events, which makes
//! event sequence numbers directly addressable:
//! `seq ∈ chunk k ⇔ k = seq / chunk_events` — the property the reservoir
//! iterators rely on for O(1) chunk location.
//!
//! A decoded chunk does **not** materialize `Event`s: it keeps the
//! uncompressed `raw` bytes plus per-event timestamp and field-offset
//! tables (one validating [`codec::scan_values`] walk at decode time),
//! and serves reads as borrowed [`EventView`]s — O(1) per event, zero
//! allocations on the read path. The raw-append ingest path builds `raw`
//! by splicing already-encoded value bytes behind a re-delta'd timestamp
//! varint ([`build_raw_event`]), so chunk files stay **byte-identical**
//! to the old encode-from-`Event` path ([`encode_chunk`], kept as the
//! reference encoder).

use crate::error::{Error, Result};
use crate::event::{codec, Event, EventView, SchemaRef};
use crate::util::varint;
use byteorder::{ByteOrder, LittleEndian};
use std::path::Path;

const MAGIC: u32 = 0x52_47_43_4B; // "RGCK"
const HEADER_LEN: usize = 4 + 8 + 8 + 4 + 1 + 8 + 4;

/// Payload compression codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    /// No compression (ablation baseline).
    None,
    /// zstd at the given level (paper: "aggressively compress" — level 1
    /// is the latency-friendly default).
    Zstd(i32),
}

impl Compression {
    fn tag(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::Zstd(_) => 1,
        }
    }
}

/// An immutable chunk of events in raw encoded form, readable as
/// borrowed [`EventView`]s.
pub struct DecodedChunk {
    /// Chunk index (sequential from 0).
    pub chunk_id: u64,
    /// Sequence number of the first event.
    pub base_seq: u64,
    schema: SchemaRef,
    /// Uncompressed event bytes (timestamps delta-encoded vs `first_ts`).
    raw: Vec<u8>,
    /// Absolute timestamp per event.
    ts: Vec<i64>,
    /// `count * arity` payload offsets into `raw` (see
    /// [`codec::scan_values`]).
    offsets: Vec<u32>,
}

impl std::fmt::Debug for DecodedChunk {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecodedChunk")
            .field("chunk_id", &self.chunk_id)
            .field("base_seq", &self.base_seq)
            .field("events", &self.ts.len())
            .finish()
    }
}

impl DecodedChunk {
    /// Assemble from pre-validated parts (the reservoir's seal path,
    /// which already holds the raw bytes and offset tables).
    pub(crate) fn from_parts(
        chunk_id: u64,
        base_seq: u64,
        schema: SchemaRef,
        raw: Vec<u8>,
        ts: Vec<i64>,
        offsets: Vec<u32>,
    ) -> DecodedChunk {
        debug_assert_eq!(offsets.len(), ts.len() * schema.len());
        DecodedChunk {
            chunk_id,
            base_seq,
            schema,
            raw,
            ts,
            offsets,
        }
    }

    /// Build a chunk from owned events (tests, tools).
    pub fn from_events(
        chunk_id: u64,
        base_seq: u64,
        events: &[Event],
        schema: &SchemaRef,
    ) -> Result<DecodedChunk> {
        let buf = encode_chunk(chunk_id, base_seq, events, schema, Compression::None)?;
        decode_chunk(&buf, schema)
    }

    /// Number of events in the chunk.
    pub fn len(&self) -> usize {
        self.ts.len()
    }

    /// True when the chunk holds no events.
    pub fn is_empty(&self) -> bool {
        self.ts.is_empty()
    }

    /// Borrowed view of the event at global sequence number `seq` (must
    /// belong to this chunk). O(1), allocation-free.
    #[inline]
    pub fn view_at(&self, seq: u64) -> EventView<'_> {
        let i = (seq - self.base_seq) as usize;
        let arity = self.schema.len();
        EventView::from_parts(
            self.ts[i],
            &self.raw,
            &self.offsets[i * arity..(i + 1) * arity],
            &self.schema,
        )
    }

    /// Timestamp of the event at `seq` without building a view.
    #[inline]
    pub fn ts_at(&self, seq: u64) -> i64 {
        self.ts[(seq - self.base_seq) as usize]
    }

    /// True if `seq` falls inside this chunk.
    #[inline]
    pub fn contains(&self, seq: u64) -> bool {
        seq >= self.base_seq && seq < self.base_seq + self.ts.len() as u64
    }

    /// Materialize every event (tests, tools — allocates freely).
    pub fn events(&self) -> Vec<Event> {
        use crate::event::EventRead;
        (0..self.ts.len() as u64)
            .map(|i| self.view_at(self.base_seq + i).to_event())
            .collect()
    }
}

/// Append one event to a chunk's raw byte stream from its already-encoded
/// value section: re-deltas only the timestamp varint and splices the
/// value bytes verbatim — no `Event` round trip, byte-identical to
/// [`codec::encode_into`] with `base_ts = first_ts`.
pub fn build_raw_event(raw: &mut Vec<u8>, ts: i64, first_ts: i64, values: &[u8]) -> u32 {
    varint::write_i64(raw, ts - first_ts);
    let val_start = raw.len() as u32;
    raw.extend_from_slice(values);
    val_start
}

/// Frame an already-built raw event stream as a chunk file image
/// (header + compressed payload + CRC trailer).
pub fn encode_chunk_payload(
    chunk_id: u64,
    base_seq: u64,
    count: usize,
    first_ts: i64,
    raw: &[u8],
    compression: Compression,
) -> Result<Vec<u8>> {
    let payload = match compression {
        Compression::None => raw.to_vec(),
        Compression::Zstd(level) => zstd::bulk::compress(raw, level)
            .map_err(|e| Error::internal(format!("zstd compress: {e}")))?,
    };
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    let mut header = [0u8; HEADER_LEN];
    LittleEndian::write_u32(&mut header[0..4], MAGIC);
    LittleEndian::write_u64(&mut header[4..12], chunk_id);
    LittleEndian::write_u64(&mut header[12..20], base_seq);
    LittleEndian::write_u32(&mut header[20..24], count as u32);
    header[24] = compression.tag();
    LittleEndian::write_i64(&mut header[25..33], first_ts);
    LittleEndian::write_u32(&mut header[33..37], raw.len() as u32);
    out.extend_from_slice(&header);
    out.extend_from_slice(&payload);
    let mut crc = [0u8; 4];
    LittleEndian::write_u32(&mut crc, crc32fast::hash(&payload));
    out.extend_from_slice(&crc);
    Ok(out)
}

/// Encode a sealed chunk from owned events — the reference encoder the
/// raw-append path must stay byte-identical to (asserted by
/// `rust/tests/view_equivalence.rs`).
pub fn encode_chunk(
    chunk_id: u64,
    base_seq: u64,
    events: &[Event],
    schema: &SchemaRef,
    compression: Compression,
) -> Result<Vec<u8>> {
    let first_ts = events.first().map(|e| e.timestamp).unwrap_or(0);
    let mut raw = Vec::with_capacity(events.len() * 32);
    for e in events {
        codec::encode_into(&mut raw, e, schema, first_ts);
    }
    encode_chunk_payload(chunk_id, base_seq, events.len(), first_ts, &raw, compression)
}

/// Decode a chunk file image produced by [`encode_chunk`] /
/// [`encode_chunk_payload`]. One validating walk builds the timestamp and
/// field-offset tables; events themselves stay in raw form.
pub fn decode_chunk(buf: &[u8], schema: &SchemaRef) -> Result<DecodedChunk> {
    if buf.len() < HEADER_LEN + 4 {
        return Err(Error::corrupt("chunk: too short"));
    }
    if LittleEndian::read_u32(&buf[0..4]) != MAGIC {
        return Err(Error::corrupt("chunk: bad magic"));
    }
    let chunk_id = LittleEndian::read_u64(&buf[4..12]);
    let base_seq = LittleEndian::read_u64(&buf[12..20]);
    let count = LittleEndian::read_u32(&buf[20..24]) as usize;
    let codec_tag = buf[24];
    let first_ts = LittleEndian::read_i64(&buf[25..33]);
    let raw_len = LittleEndian::read_u32(&buf[33..37]) as usize;
    let payload = &buf[HEADER_LEN..buf.len() - 4];
    let crc = LittleEndian::read_u32(&buf[buf.len() - 4..]);
    if crc32fast::hash(payload) != crc {
        return Err(Error::corrupt("chunk: crc mismatch"));
    }
    let raw = match codec_tag {
        0 => payload.to_vec(),
        1 => zstd::bulk::decompress(payload, raw_len)
            .map_err(|e| Error::corrupt(format!("chunk: zstd: {e}")))?,
        t => return Err(Error::corrupt(format!("chunk: unknown codec {t}"))),
    };
    if raw.len() != raw_len {
        return Err(Error::corrupt("chunk: raw length mismatch"));
    }
    let mut ts = Vec::with_capacity(count);
    let mut offsets = Vec::with_capacity(count * schema.len());
    let mut pos = 0usize;
    for _ in 0..count {
        ts.push(first_ts + varint::read_i64(&raw, &mut pos)?);
        codec::scan_values(&raw, &mut pos, schema, &mut offsets)?;
    }
    if pos != raw.len() {
        return Err(Error::corrupt("chunk: trailing bytes after events"));
    }
    Ok(DecodedChunk {
        chunk_id,
        base_seq,
        schema: schema.clone(),
        raw,
        ts,
        offsets,
    })
}

/// Chunk file name.
pub fn chunk_file_name(chunk_id: u64) -> String {
    format!("{chunk_id:016}.chk")
}

/// Read + decode a chunk file.
pub fn read_chunk_file(dir: &Path, chunk_id: u64, schema: &SchemaRef) -> Result<DecodedChunk> {
    let path = dir.join(chunk_file_name(chunk_id));
    let buf = std::fs::read(&path)
        .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{path:?}: {e}"))))?;
    let c = decode_chunk(&buf, schema)?;
    if c.chunk_id != chunk_id {
        return Err(Error::corrupt(format!(
            "chunk file {path:?} claims id {}",
            c.chunk_id
        )));
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{EventRead, FieldType, Schema, Value};
    use crate::util::rng::Rng;

    fn schema() -> SchemaRef {
        Schema::of(&[("card", FieldType::Str), ("amount", FieldType::F64)]).unwrap()
    }

    fn events(n: usize, base_ts: i64) -> Vec<Event> {
        let mut rng = Rng::new(1);
        (0..n)
            .map(|i| {
                Event::new(
                    base_ts + i as i64 * 10,
                    vec![
                        Value::Str(format!("card_{}", rng.next_below(50))),
                        Value::F64(rng.next_lognormal(3.0, 1.0)),
                    ],
                )
            })
            .collect()
    }

    #[test]
    fn roundtrip_zstd() {
        let s = schema();
        let evs = events(256, 1_600_000_000_000);
        let buf = encode_chunk(3, 768, &evs, &s, Compression::Zstd(1)).unwrap();
        let c = decode_chunk(&buf, &s).unwrap();
        assert_eq!(c.chunk_id, 3);
        assert_eq!(c.base_seq, 768);
        assert_eq!(c.events(), evs);
    }

    #[test]
    fn roundtrip_uncompressed() {
        let s = schema();
        let evs = events(64, 0);
        let buf = encode_chunk(0, 0, &evs, &s, Compression::None).unwrap();
        let c = decode_chunk(&buf, &s).unwrap();
        assert_eq!(c.events(), evs);
    }

    #[test]
    fn compression_shrinks_realistic_events() {
        let s = schema();
        let evs = events(512, 1_600_000_000_000);
        let plain = encode_chunk(0, 0, &evs, &s, Compression::None).unwrap();
        let zstd1 = encode_chunk(0, 0, &evs, &s, Compression::Zstd(1)).unwrap();
        assert!(
            (zstd1.len() as f64) < plain.len() as f64 * 0.8,
            "zstd {} vs plain {}",
            zstd1.len(),
            plain.len()
        );
    }

    #[test]
    fn corrupt_payload_detected() {
        let s = schema();
        let evs = events(16, 0);
        let mut buf = encode_chunk(0, 0, &evs, &s, Compression::Zstd(1)).unwrap();
        let mid = buf.len() / 2;
        buf[mid] ^= 0xff;
        assert!(decode_chunk(&buf, &s).is_err());
    }

    #[test]
    fn truncated_chunk_detected() {
        let s = schema();
        let evs = events(16, 0);
        let buf = encode_chunk(0, 0, &evs, &s, Compression::Zstd(1)).unwrap();
        for cut in [0usize, 10, HEADER_LEN, buf.len() - 1] {
            assert!(decode_chunk(&buf[..cut], &s).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn view_at_and_contains() {
        let s = schema();
        let evs = events(10, 100);
        let buf = encode_chunk(2, 20, &evs, &s, Compression::None).unwrap();
        let c = decode_chunk(&buf, &s).unwrap();
        assert!(c.contains(20) && c.contains(29));
        assert!(!c.contains(19) && !c.contains(30));
        assert_eq!(c.view_at(25).to_event(), evs[5]);
        assert_eq!(c.ts_at(25), evs[5].timestamp);
    }

    #[test]
    fn raw_event_splice_matches_reference_encoder() {
        // build_raw_event over pre-encoded value bytes must produce the
        // same raw stream the reference encoder does
        let s = schema();
        let evs = events(32, 5_000);
        let first_ts = evs[0].timestamp;
        let mut reference = Vec::new();
        for e in &evs {
            codec::encode_into(&mut reference, e, &s, first_ts);
        }
        let mut spliced = Vec::new();
        for e in &evs {
            let mut values = Vec::new();
            codec::encode_values_into(&mut values, e, &s);
            build_raw_event(&mut spliced, e.timestamp, first_ts, &values);
        }
        assert_eq!(reference, spliced);
    }

    #[test]
    fn file_roundtrip() {
        let s = schema();
        let tmp = crate::util::tmp::TempDir::new("chunkfile");
        let evs = events(32, 500);
        let buf = encode_chunk(7, 224, &evs, &s, Compression::Zstd(1)).unwrap();
        std::fs::write(tmp.path().join(chunk_file_name(7)), &buf).unwrap();
        let c = read_chunk_file(tmp.path(), 7, &s).unwrap();
        assert_eq!(c.events(), evs);
        assert!(read_chunk_file(tmp.path(), 8, &s).is_err(), "missing file");
    }

    #[test]
    fn empty_chunk_roundtrip() {
        let s = schema();
        let buf = encode_chunk(0, 0, &[], &s, Compression::Zstd(1)).unwrap();
        let c = decode_chunk(&buf, &s).unwrap();
        assert!(c.is_empty());
    }
}
