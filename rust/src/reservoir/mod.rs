//! The **event reservoir** (paper §3.3.1) — Railgun's core storage
//! component and the enabler of real sliding windows over long horizons.
//!
//! Events are appended to an in-memory *open chunk* in **raw encoded
//! form** ([`Reservoir::append_raw`] copies already-encoded value bytes
//! once, validating as it scans — the zero-allocation ingest path; the
//! owned-event [`Reservoir::append`] encodes into a reusable scratch and
//! delegates). When the open chunk reaches a fixed event count it is
//! *sealed*: the raw bytes are framed (timestamps re-delta'd in place,
//! no `Event` round trip), compressed, and handed to a background writer
//! thread that persists an immutable, ordered chunk file. I/O is
//! therefore never on the event-processing path. Reads — open or sealed
//! — serve borrowed [`EventView`]s over the raw bytes via precomputed
//! field-offset tables. Windows read the reservoir through
//! [`ResIterator`]s; when an
//! iterator starts a new chunk, the *adjacent* chunk is eagerly loaded
//! into the shared [`cache::ChunkCache`] by a background prefetch thread,
//! so advancing windows find their next chunk already in memory (the
//! paper's claim that "windows of years are equivalent to windows of
//! seconds").
//!
//! Durability contract: sealed chunks are durable; open-chunk events are
//! lost on crash and recovered by replaying the messaging layer from the
//! last sealed sequence number ([`Reservoir::durable_len`]).

pub mod cache;
pub mod chunk;
mod iterator;

pub use cache::CacheStats;
pub use chunk::{Compression, DecodedChunk};
pub use iterator::ResIterator;

use crate::error::{Error, Result};
use crate::event::{codec, Event, EventView, SchemaRef};
use crate::util::hash::FxHashMap;
use cache::ChunkCache;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};

/// Reservoir tuning knobs.
#[derive(Debug, Clone)]
pub struct ReservoirConfig {
    /// Directory for chunk files.
    pub dir: PathBuf,
    /// Events per sealed chunk (fixed ⇒ O(1) seq→chunk addressing).
    pub chunk_events: usize,
    /// Chunk cache capacity (the paper's Fig. 6 experiment uses 220).
    pub cache_chunks: usize,
    /// Payload compression.
    pub compression: Compression,
    /// Eager adjacent-chunk caching (ablation switch).
    pub prefetch: bool,
    /// fsync chunk files after write.
    pub fsync: bool,
}

impl ReservoirConfig {
    /// Defaults tuned for the benchmarks (512-event chunks, 220-chunk
    /// cache — the paper's cache size).
    pub fn new(dir: PathBuf) -> Self {
        ReservoirConfig {
            dir,
            chunk_events: 512,
            cache_chunks: 220,
            compression: Compression::Zstd(1),
            prefetch: true,
            fsync: false,
        }
    }
}

/// Per-event bookkeeping inside the open chunk's raw buffer.
#[derive(Debug, Clone, Copy)]
pub(crate) struct OpenEventMeta {
    /// Absolute event timestamp.
    pub ts: i64,
    /// Value-section range in [`OpenChunk::buf`].
    pub start: u32,
    pub end: u32,
}

/// Open (mutable) chunk state shared between the reservoir and tail
/// iterators. Events are kept in **raw encoded form** (value sections
/// concatenated in `buf`, field offsets precomputed), so appends copy
/// bytes instead of materializing `Event`s, and reads serve borrowed
/// [`EventView`]s.
#[derive(Debug)]
pub(crate) struct OpenChunk {
    pub base_seq: u64,
    /// Concatenated value sections (no timestamp varints — timestamps
    /// live in `meta` and are re-delta'd at seal time).
    pub buf: Vec<u8>,
    pub meta: Vec<OpenEventMeta>,
    /// `meta.len() * arity` payload offsets into `buf`.
    pub offsets: Vec<u32>,
}

impl OpenChunk {
    pub(crate) fn len(&self) -> usize {
        self.meta.len()
    }

    /// Borrowed view of the event at absolute `seq`, if it lives in the
    /// open chunk.
    pub(crate) fn view_at<'a>(&'a self, seq: u64, schema: &'a SchemaRef) -> Option<EventView<'a>> {
        let i = seq.checked_sub(self.base_seq)? as usize;
        if i >= self.meta.len() {
            return None;
        }
        let arity = schema.len();
        Some(EventView::from_parts(
            self.meta[i].ts,
            &self.buf,
            &self.offsets[i * arity..(i + 1) * arity],
            schema,
        ))
    }
}

/// State shared with iterators and background threads.
pub(crate) struct Shared {
    pub dir: PathBuf,
    pub schema: SchemaRef,
    pub chunk_events: usize,
    pub prefetch: bool,
    pub cache: Mutex<ChunkCache>,
    pub stats: Arc<CacheStats>,
    /// Sealed chunks whose file write has not completed yet.
    pub pending: Mutex<FxHashMap<u64, Arc<DecodedChunk>>>,
    /// Number of sealed chunks (files that exist or are pending).
    pub sealed_chunks: AtomicU64,
    /// Prefetch request queue (None after shutdown).
    pub prefetch_tx: Mutex<Option<Sender<u64>>>,
    /// Set when the writer thread hits an I/O error.
    pub write_failed: AtomicBool,
}

impl Shared {
    /// Fetch a sealed chunk: cache → pending → synchronous file read.
    pub(crate) fn chunk(&self, chunk_id: u64) -> Result<Arc<DecodedChunk>> {
        if let Some(c) = self.cache.lock().unwrap().get(chunk_id) {
            return Ok(c);
        }
        if let Some(c) = self.pending.lock().unwrap().get(&chunk_id) {
            return Ok(c.clone());
        }
        // cache miss: blocking read (exactly what prefetch should avoid)
        let c = Arc::new(chunk::read_chunk_file(&self.dir, chunk_id, &self.schema)?);
        self.cache.lock().unwrap().insert(c.clone());
        Ok(c)
    }

    /// Ask the background loader to warm `chunk_id`.
    pub(crate) fn request_prefetch(&self, chunk_id: u64) {
        if !self.prefetch || chunk_id >= self.sealed_chunks.load(Ordering::Acquire) {
            return;
        }
        if self.cache.lock().unwrap().peek(chunk_id).is_some() {
            return;
        }
        if let Some(tx) = self.prefetch_tx.lock().unwrap().as_ref() {
            if tx.send(chunk_id).is_ok() {
                self.stats.prefetch_issued.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

enum WriteJob {
    Chunk { chunk_id: u64, bytes: Vec<u8> },
    Sync(Sender<()>),
    Shutdown,
}

/// The disk-backed event reservoir. One per task processor.
pub struct Reservoir {
    shared: Arc<Shared>,
    open: Arc<RwLock<OpenChunk>>,
    next_seq: u64,
    writer_tx: Sender<WriteJob>,
    writer: Option<std::thread::JoinHandle<()>>,
    prefetcher: Option<std::thread::JoinHandle<()>>,
    compression: Compression,
    /// Reusable value-encode buffer for the owned-event [`Reservoir::append`]
    /// compatibility path.
    encode_scratch: Vec<u8>,
}

impl std::fmt::Debug for Reservoir {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Reservoir")
            .field("dir", &self.shared.dir)
            .field("next_seq", &self.next_seq)
            .field(
                "sealed_chunks",
                &self.shared.sealed_chunks.load(Ordering::Relaxed),
            )
            .finish()
    }
}

impl Reservoir {
    /// Open a reservoir, recovering sealed chunks from `config.dir`.
    ///
    /// After recovery, [`Self::len`] == [`Self::durable_len`]; the caller
    /// must replay newer events from the messaging layer.
    pub fn open(config: ReservoirConfig, schema: SchemaRef) -> Result<Reservoir> {
        std::fs::create_dir_all(&config.dir)?;
        if config.chunk_events == 0 {
            return Err(Error::invalid("chunk_events must be > 0"));
        }
        // recover: sealed chunks must be contiguous 0..n
        let mut ids: Vec<u64> = Vec::new();
        for entry in std::fs::read_dir(&config.dir)? {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".chk") {
                ids.push(
                    stem.parse()
                        .map_err(|_| Error::corrupt(format!("bad chunk file {name}")))?,
                );
            }
        }
        ids.sort_unstable();
        let mut sealed = 0u64;
        for id in &ids {
            if *id == sealed {
                sealed += 1;
            } else {
                // gap ⇒ later files are unreachable leftovers; ignore them
                log::warn!(
                    "reservoir {}: ignoring non-contiguous chunk {id}",
                    config.dir.display()
                );
                break;
            }
        }

        let stats = Arc::new(CacheStats::default());
        let (prefetch_tx, prefetch_rx) = std::sync::mpsc::channel::<u64>();
        let shared = Arc::new(Shared {
            dir: config.dir.clone(),
            schema,
            chunk_events: config.chunk_events,
            prefetch: config.prefetch,
            cache: Mutex::new(ChunkCache::new(config.cache_chunks, stats.clone())),
            stats,
            pending: Mutex::new(FxHashMap::default()),
            sealed_chunks: AtomicU64::new(sealed),
            prefetch_tx: Mutex::new(Some(prefetch_tx)),
            write_failed: AtomicBool::new(false),
        });

        let (writer_tx, writer_rx) = std::sync::mpsc::channel::<WriteJob>();
        let writer = std::thread::Builder::new()
            .name("reservoir-writer".into())
            .spawn({
                let shared = shared.clone();
                let fsync = config.fsync;
                move || writer_loop(shared, writer_rx, fsync)
            })
            .map_err(|e| Error::internal(format!("spawn writer: {e}")))?;
        let prefetcher = std::thread::Builder::new()
            .name("reservoir-prefetch".into())
            .spawn({
                let shared = shared.clone();
                move || prefetch_loop(shared, prefetch_rx)
            })
            .map_err(|e| Error::internal(format!("spawn prefetcher: {e}")))?;

        let next_seq = sealed * config.chunk_events as u64;
        Ok(Reservoir {
            shared: shared.clone(),
            open: Arc::new(RwLock::new(OpenChunk {
                base_seq: next_seq,
                buf: Vec::with_capacity(config.chunk_events * 32),
                meta: Vec::with_capacity(config.chunk_events),
                offsets: Vec::new(),
            })),
            next_seq,
            writer_tx,
            writer: Some(writer),
            prefetcher: Some(prefetcher),
            compression: config.compression,
            encode_scratch: Vec::with_capacity(64),
        })
    }

    /// Append an owned event; returns its sequence number. Encodes the
    /// value section into a reusable scratch and delegates to the raw
    /// path — events land in the reservoir in raw form either way, so
    /// both paths produce byte-identical chunks.
    pub fn append(&mut self, event: &Event) -> Result<u64> {
        let mut scratch = std::mem::take(&mut self.encode_scratch);
        scratch.clear();
        codec::encode_values_into(&mut scratch, event, &self.shared.schema);
        let r = self.append_raw(event.timestamp, &scratch);
        self.encode_scratch = scratch;
        r
    }

    /// Append an event from its already-encoded value section (the bytes
    /// after the timestamp varint of the standalone event codec) — the
    /// **zero-allocation ingest path**: the bytes are validated as they
    /// are scanned into the open chunk's offset table and copied once;
    /// no `Event`, no `Vec<Value>`, no `String`s. Seals + hands off the
    /// chunk to the writer thread when full (no I/O on this path).
    pub fn append_raw(&mut self, ts: i64, values: &[u8]) -> Result<u64> {
        let seq = self.next_seq;
        let seal = {
            let mut open = self.open.write().unwrap();
            let start = open.buf.len();
            if start + values.len() >= codec::NULL_OFFSET as usize {
                return Err(Error::invalid("reservoir: open chunk exceeds 4 GiB"));
            }
            let offsets_len = open.offsets.len();
            let OpenChunk {
                buf, offsets: offs, ..
            } = &mut *open;
            buf.extend_from_slice(values);
            let mut pos = start;
            let scanned = codec::scan_values(buf, &mut pos, &self.shared.schema, offs)
                .and_then(|()| {
                    if pos != buf.len() {
                        Err(Error::corrupt(format!(
                            "event: {} trailing bytes",
                            buf.len() - pos
                        )))
                    } else {
                        Ok(())
                    }
                });
            if let Err(e) = scanned {
                // reject atomically: the open chunk is unchanged
                buf.truncate(start);
                offs.truncate(offsets_len);
                return Err(e);
            }
            let end = open.buf.len() as u32;
            open.meta.push(OpenEventMeta {
                ts,
                start: start as u32,
                end,
            });
            open.meta.len() >= self.shared.chunk_events
        };
        self.next_seq += 1;
        if seal {
            self.seal()?;
        }
        Ok(seq)
    }

    fn seal(&mut self) -> Result<()> {
        let (base_seq, count, first_ts, raw, ts_vec, offsets) = {
            let mut open = self.open.write().unwrap();
            let count = open.len();
            let first_ts = open.meta.first().map(|m| m.ts).unwrap_or(0);
            let arity = self.shared.schema.len();
            // splice the raw value bytes behind re-delta'd timestamp
            // varints — no Event round trip, byte-identical to the
            // reference encoder (chunk::encode_chunk)
            let mut raw = Vec::with_capacity(open.buf.len() + count * 5);
            let mut ts_vec = Vec::with_capacity(count);
            let mut offsets = Vec::with_capacity(count * arity);
            for (i, m) in open.meta.iter().enumerate() {
                let val_start = chunk::build_raw_event(
                    &mut raw,
                    m.ts,
                    first_ts,
                    &open.buf[m.start as usize..m.end as usize],
                );
                for &o in &open.offsets[i * arity..(i + 1) * arity] {
                    offsets.push(if o == codec::NULL_OFFSET {
                        codec::NULL_OFFSET
                    } else {
                        o - m.start + val_start
                    });
                }
                ts_vec.push(m.ts);
            }
            let base = open.base_seq;
            open.base_seq = base + count as u64;
            open.buf.clear();
            open.meta.clear();
            open.offsets.clear();
            (base, count, first_ts, raw, ts_vec, offsets)
        };
        let chunk_id = base_seq / self.shared.chunk_events as u64;
        let bytes = chunk::encode_chunk_payload(
            chunk_id,
            base_seq,
            count,
            first_ts,
            &raw,
            self.compression,
        )?;
        let decoded = Arc::new(DecodedChunk::from_parts(
            chunk_id,
            base_seq,
            self.shared.schema.clone(),
            raw,
            ts_vec,
            offsets,
        ));
        // newest chunk is hot: put it in both pending (until durable) and
        // the cache (tail-adjacent iterators will want it)
        self.shared
            .pending
            .lock()
            .unwrap()
            .insert(chunk_id, decoded.clone());
        self.shared.cache.lock().unwrap().insert(decoded);
        self.shared
            .sealed_chunks
            .store(chunk_id + 1, Ordering::Release);
        self.writer_tx
            .send(WriteJob::Chunk { chunk_id, bytes })
            .map_err(|_| Error::closed("reservoir writer thread gone"))?;
        Ok(())
    }

    /// Total events appended (including the open chunk).
    pub fn len(&self) -> u64 {
        self.next_seq
    }

    /// True when no events have been appended.
    pub fn is_empty(&self) -> bool {
        self.next_seq == 0
    }

    /// Events that survive a crash (sealed chunks only).
    pub fn durable_len(&self) -> u64 {
        self.shared.sealed_chunks.load(Ordering::Acquire) * self.shared.chunk_events as u64
    }

    /// Chunks sealed so far (telemetry pull; monotonic).
    pub fn sealed_chunks(&self) -> u64 {
        self.shared.sealed_chunks.load(Ordering::Acquire)
    }

    /// Bytes buffered in the open (unsealed) chunk (telemetry pull).
    pub fn open_chunk_bytes(&self) -> u64 {
        self.open.read().unwrap().buf.len() as u64
    }

    /// Create an iterator positioned at `seq`.
    pub fn iterator_at(&self, seq: u64) -> ResIterator {
        ResIterator::new(self.shared.clone(), self.open.clone(), seq)
    }

    /// Cache statistics handle.
    pub fn cache_stats(&self) -> Arc<CacheStats> {
        self.shared.stats.clone()
    }

    /// Chunks currently resident (cache + pending writes).
    pub fn resident_chunks(&self) -> usize {
        let c = self.shared.cache.lock().unwrap().len();
        let p = self.shared.pending.lock().unwrap().len();
        c + p
    }

    /// Event schema.
    pub fn schema(&self) -> &SchemaRef {
        &self.shared.schema
    }

    /// Block until every queued chunk write is durable. Errors if the
    /// writer thread reported an I/O failure.
    pub fn sync(&self) -> Result<()> {
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        self.writer_tx
            .send(WriteJob::Sync(ack_tx))
            .map_err(|_| Error::closed("reservoir writer thread gone"))?;
        ack_rx
            .recv()
            .map_err(|_| Error::closed("reservoir writer thread gone"))?;
        if self.shared.write_failed.load(Ordering::Acquire) {
            return Err(Error::internal("reservoir: chunk write failed (see log)"));
        }
        Ok(())
    }
}

impl Drop for Reservoir {
    fn drop(&mut self) {
        let _ = self.writer_tx.send(WriteJob::Shutdown);
        *self.shared.prefetch_tx.lock().unwrap() = None;
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prefetcher.take() {
            let _ = h.join();
        }
    }
}

fn writer_loop(shared: Arc<Shared>, rx: Receiver<WriteJob>, fsync: bool) {
    use std::io::Write;
    while let Ok(job) = rx.recv() {
        match job {
            WriteJob::Chunk { chunk_id, bytes } => {
                let path = shared.dir.join(chunk::chunk_file_name(chunk_id));
                let result = (|| -> std::io::Result<()> {
                    let mut f = std::fs::File::create(&path)?;
                    f.write_all(&bytes)?;
                    if fsync {
                        f.sync_data()?;
                    }
                    Ok(())
                })();
                match result {
                    Ok(()) => {
                        // durable: the cache/file now serve reads
                        shared.pending.lock().unwrap().remove(&chunk_id);
                    }
                    Err(e) => {
                        log::error!("reservoir: writing chunk {chunk_id} failed: {e}");
                        shared.write_failed.store(true, Ordering::Release);
                        // keep it in pending so reads still work
                    }
                }
            }
            WriteJob::Sync(ack) => {
                let _ = ack.send(());
            }
            WriteJob::Shutdown => break,
        }
    }
}

fn prefetch_loop(shared: Arc<Shared>, rx: Receiver<u64>) {
    while let Ok(chunk_id) = rx.recv() {
        let done = |s: &Shared| {
            s.stats
                .prefetch_done
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        };
        if shared.cache.lock().unwrap().peek(chunk_id).is_some() {
            done(&shared);
            continue;
        }
        if let Some(c) = shared.pending.lock().unwrap().get(&chunk_id).cloned() {
            shared.cache.lock().unwrap().insert(c);
            done(&shared);
            continue;
        }
        match chunk::read_chunk_file(&shared.dir, chunk_id, &shared.schema) {
            Ok(c) => {
                shared.cache.lock().unwrap().insert(Arc::new(c));
                done(&shared);
            }
            Err(e) => {
                // non-fatal: the iterator will fall back to a sync read
                log::debug!("prefetch of chunk {chunk_id} failed: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::event::{EventRead, FieldType, Schema, Value};
    use crate::util::tmp::TempDir;

    fn schema() -> SchemaRef {
        Schema::of(&[("card", FieldType::Str), ("amount", FieldType::F64)]).unwrap()
    }

    fn ev(i: u64) -> Event {
        Event::new(
            1000 + i as i64,
            vec![
                Value::Str(format!("card_{}", i % 7)),
                Value::F64(i as f64 * 0.5),
            ],
        )
    }

    fn config(tmp: &TempDir) -> ReservoirConfig {
        ReservoirConfig {
            chunk_events: 16,
            cache_chunks: 8,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        }
    }

    #[test]
    fn append_assigns_sequential_seqs() {
        let tmp = TempDir::new("res_seq");
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        for i in 0..100 {
            assert_eq!(r.append(&ev(i)).unwrap(), i);
        }
        assert_eq!(r.len(), 100);
        // 100 events / 16 per chunk = 6 sealed
        r.sync().unwrap();
        assert_eq!(r.durable_len(), 96);
    }

    #[test]
    fn iterate_all_events_across_chunks() {
        let tmp = TempDir::new("res_iter");
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        let events: Vec<Event> = (0..100).map(ev).collect();
        for e in &events {
            r.append(e).unwrap();
        }
        let mut it = r.iterator_at(0);
        let mut got = Vec::new();
        while let Some(e) = it.next(|_, e| e.to_event()).unwrap() {
            got.push(e);
        }
        assert_eq!(got, events);
        assert_eq!(it.seq(), 100);
        // at the end: peek is None
        assert_eq!(it.peek_ts().unwrap(), None);
    }

    #[test]
    fn iterator_sees_open_chunk_immediately() {
        let tmp = TempDir::new("res_open");
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        let mut it = r.iterator_at(0);
        assert_eq!(it.peek_ts().unwrap(), None);
        r.append(&ev(0)).unwrap();
        assert_eq!(it.peek_ts().unwrap(), Some(1000));
    }

    #[test]
    fn iterator_starting_mid_stream() {
        let tmp = TempDir::new("res_mid");
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        for i in 0..64 {
            r.append(&ev(i)).unwrap();
        }
        let mut it = r.iterator_at(40);
        let first = it.next(|seq, e| (seq, e.timestamp())).unwrap().unwrap();
        assert_eq!(first, (40, 1040));
    }

    #[test]
    fn recovery_keeps_sealed_drops_open() {
        let tmp = TempDir::new("res_recover");
        {
            let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
            for i in 0..50 {
                r.append(&ev(i)).unwrap();
            }
            r.sync().unwrap();
        } // 48 sealed (3 chunks), 2 open lost
        let r = Reservoir::open(config(&tmp), schema()).unwrap();
        assert_eq!(r.len(), 48);
        assert_eq!(r.durable_len(), 48);
        let mut it = r.iterator_at(0);
        let mut n = 0;
        while it.next(|_, _| ()).unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 48);
    }

    #[test]
    fn recovered_reservoir_accepts_appends() {
        let tmp = TempDir::new("res_reappend");
        {
            let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
            for i in 0..32 {
                r.append(&ev(i)).unwrap();
            }
            r.sync().unwrap();
        }
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        assert_eq!(r.append(&ev(32)).unwrap(), 32);
        let mut it = r.iterator_at(30);
        let seqs: (u64, u64, u64) = {
            let a = it.next(|s, _| s).unwrap().unwrap();
            let b = it.next(|s, _| s).unwrap().unwrap();
            let c = it.next(|s, _| s).unwrap().unwrap();
            (a, b, c)
        };
        assert_eq!(seqs, (30, 31, 32));
    }

    #[test]
    fn cold_iteration_reads_from_disk() {
        let tmp = TempDir::new("res_cold");
        let cfg = ReservoirConfig {
            chunk_events: 16,
            cache_chunks: 2, // tiny cache: old chunks must be evicted
            prefetch: false, // force synchronous misses
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        let mut r = Reservoir::open(cfg, schema()).unwrap();
        for i in 0..160 {
            r.append(&ev(i)).unwrap();
        }
        r.sync().unwrap();
        let stats = r.cache_stats();
        let misses_before = stats.snapshot().1;
        let mut it = r.iterator_at(0);
        let mut n = 0;
        while it.next(|_, _| ()).unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 160);
        assert!(
            stats.snapshot().1 > misses_before,
            "old chunks must be disk reads"
        );
    }

    #[test]
    fn prefetch_warms_next_chunk() {
        let tmp = TempDir::new("res_prefetch");
        let cfg = ReservoirConfig {
            chunk_events: 64,
            cache_chunks: 4,
            prefetch: true,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        let mut r = Reservoir::open(cfg, schema()).unwrap();
        for i in 0..(64 * 30) {
            r.append(&ev(i)).unwrap();
        }
        r.sync().unwrap();
        let stats = r.cache_stats();
        // walk a head iterator through all chunks, pausing to let the
        // prefetcher keep up (it has its own thread)
        let mut it = r.iterator_at(0);
        let mut n = 0u64;
        while it.next(|_, _| ()).unwrap().is_some() {
            n += 1;
            if n % 64 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        assert_eq!(n, 64 * 30);
        let (_h, _m, issued, done, _) = stats.snapshot();
        assert!(issued > 10, "prefetches were issued: {issued}");
        assert!(done > 0, "prefetches completed: {done}");
    }

    #[test]
    fn compression_none_roundtrips() {
        let tmp = TempDir::new("res_nocomp");
        let cfg = ReservoirConfig {
            chunk_events: 8,
            compression: Compression::None,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        let mut r = Reservoir::open(cfg, schema()).unwrap();
        for i in 0..20 {
            r.append(&ev(i)).unwrap();
        }
        r.sync().unwrap();
        let mut it = r.iterator_at(0);
        let mut n = 0;
        while it.next(|_, _| ()).unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 20);
    }

    #[test]
    fn two_iterators_are_independent() {
        let tmp = TempDir::new("res_two_iters");
        let mut r = Reservoir::open(config(&tmp), schema()).unwrap();
        for i in 0..50 {
            r.append(&ev(i)).unwrap();
        }
        let mut head = r.iterator_at(0);
        let mut tail = r.iterator_at(45);
        assert_eq!(head.next(|s, _| s).unwrap(), Some(0));
        assert_eq!(tail.next(|s, _| s).unwrap(), Some(45));
        assert_eq!(head.next(|s, _| s).unwrap(), Some(1));
    }

    #[test]
    fn zero_chunk_events_rejected() {
        let tmp = TempDir::new("res_zero");
        let cfg = ReservoirConfig {
            chunk_events: 0,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        assert!(Reservoir::open(cfg, schema()).is_err());
    }
}
