//! Reservoir iterators.
//!
//! A window holds two of these: a **head** iterator (expiring events) and
//! a **tail** iterator (arriving events) — Figure 3 of the paper. Each
//! iterator pins at most one decoded chunk (`current`); entering a new
//! sealed chunk triggers an eager prefetch of the *next* chunk so the
//! upcoming transition is a cache hit.
//!
//! Events are exposed by callback (`next(|seq, event| ...)`) rather than
//! by reference return: events in the open chunk live behind a lock, and
//! the callback shape lets both sealed and open chunks be served
//! zero-copy. The callback receives a borrowed [`EventView`] — chunks
//! (sealed and open) store events in raw encoded form with precomputed
//! field-offset tables, so serving a view is O(1) and allocation-free.

use crate::error::Result;
use crate::event::EventView;
use crate::reservoir::chunk::DecodedChunk;
use crate::reservoir::{OpenChunk, Shared};
use crate::util::clock::TimestampMs;
use std::sync::atomic::Ordering;
use std::sync::{Arc, RwLock};

/// A forward iterator over the reservoir's event sequence.
pub struct ResIterator {
    shared: Arc<Shared>,
    open: Arc<RwLock<OpenChunk>>,
    seq: u64,
    current: Option<Arc<DecodedChunk>>,
}

impl std::fmt::Debug for ResIterator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResIterator")
            .field("seq", &self.seq)
            .field("chunk", &self.current.as_ref().map(|c| c.chunk_id))
            .finish()
    }
}

impl ResIterator {
    pub(crate) fn new(shared: Arc<Shared>, open: Arc<RwLock<OpenChunk>>, seq: u64) -> Self {
        ResIterator {
            shared,
            open,
            seq,
            current: None,
        }
    }

    /// Next sequence number this iterator will yield.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Timestamp of the next event, or `None` at the end of the stream.
    pub fn peek_ts(&mut self) -> Result<Option<TimestampMs>> {
        self.with_next(|_, e| e.timestamp())
    }

    /// If an event is available, call `f(seq, &view)`, advance, and
    /// return its result.
    pub fn next<R>(&mut self, f: impl FnOnce(u64, &EventView<'_>) -> R) -> Result<Option<R>> {
        let r = self.with_next(f)?;
        if r.is_some() {
            self.seq += 1;
        }
        Ok(r)
    }

    /// Ensure the sealed chunk containing `self.seq` is pinned.
    fn pin_sealed(&mut self) -> Result<()> {
        let chunk_id = self.seq / self.shared.chunk_events as u64;
        let need_load = match &self.current {
            Some(c) => !c.contains(self.seq),
            None => true,
        };
        if need_load {
            let c = self.shared.chunk(chunk_id)?;
            // eager caching: warm the adjacent chunk as this one
            // starts being iterated (paper §3.3.1)
            self.shared.request_prefetch(chunk_id + 1);
            self.current = Some(c);
        }
        Ok(())
    }

    /// Call `f` on the next event without advancing.
    fn with_next<R>(&mut self, f: impl FnOnce(u64, &EventView<'_>) -> R) -> Result<Option<R>> {
        let sealed_chunks = self.shared.sealed_chunks.load(Ordering::Acquire);
        let sealed_events = sealed_chunks * self.shared.chunk_events as u64;
        if self.seq < sealed_events {
            self.pin_sealed()?;
            let c = self.current.as_ref().expect("just loaded");
            return Ok(Some(f(self.seq, &c.view_at(self.seq))));
        }
        // open chunk
        let open = self.open.read().unwrap();
        match open.view_at(self.seq, &self.shared.schema) {
            Some(v) => Ok(Some(f(self.seq, &v))),
            None => Ok(None),
        }
    }

    /// Jump to an absolute sequence number (used by window alignment and
    /// backfill).
    pub fn seek(&mut self, seq: u64) {
        self.seq = seq;
        if let Some(c) = &self.current {
            if !c.contains(seq) {
                self.current = None;
            }
        }
    }

    /// Drop the pinned chunk (memory accounting hooks in benches).
    pub fn unpin(&mut self) {
        self.current = None;
    }
}

#[cfg(test)]
mod tests {
    use crate::event::{Event, EventRead, FieldType, Schema, Value, ValueRef};
    use crate::reservoir::{Reservoir, ReservoirConfig};
    use crate::util::tmp::TempDir;

    fn setup(n: u64, chunk_events: usize) -> (TempDir, Reservoir) {
        let tmp = TempDir::new("resiter");
        let schema = Schema::of(&[("v", FieldType::I64)]).unwrap();
        let cfg = ReservoirConfig {
            chunk_events,
            cache_chunks: 4,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        let mut r = Reservoir::open(cfg, schema).unwrap();
        for i in 0..n {
            r.append(&Event::new(i as i64 * 100, vec![Value::I64(i as i64)]))
                .unwrap();
        }
        (tmp, r)
    }

    #[test]
    fn peek_does_not_advance() {
        let (_tmp, r) = setup(10, 4);
        let mut it = r.iterator_at(0);
        assert_eq!(it.peek_ts().unwrap(), Some(0));
        assert_eq!(it.peek_ts().unwrap(), Some(0));
        assert_eq!(it.seq(), 0);
        it.next(|_, _| ()).unwrap();
        assert_eq!(it.peek_ts().unwrap(), Some(100));
    }

    #[test]
    fn values_and_seqs_match() {
        let (_tmp, r) = setup(40, 8);
        let mut it = r.iterator_at(0);
        for i in 0..40u64 {
            let (seq, v) = it
                .next(|s, e| {
                    let v = match e.value_ref(0) {
                        ValueRef::I64(v) => v,
                        _ => panic!(),
                    };
                    (s, v)
                })
                .unwrap()
                .unwrap();
            assert_eq!(seq, i);
            assert_eq!(v, i as i64);
        }
        assert!(it.next(|_, _| ()).unwrap().is_none());
    }

    #[test]
    fn seek_moves_both_ways() {
        let (_tmp, r) = setup(64, 8);
        let mut it = r.iterator_at(0);
        it.seek(50);
        assert_eq!(it.next(|s, _| s).unwrap(), Some(50));
        it.seek(3);
        assert_eq!(it.next(|s, _| s).unwrap(), Some(3));
        // seek within the same chunk keeps the pinned chunk
        it.seek(5);
        assert_eq!(it.next(|s, _| s).unwrap(), Some(5));
    }

    #[test]
    fn iterator_catches_up_with_appends() {
        let tmp = TempDir::new("resiter_live");
        let schema = Schema::of(&[("v", FieldType::I64)]).unwrap();
        let cfg = ReservoirConfig {
            chunk_events: 4,
            cache_chunks: 4,
            ..ReservoirConfig::new(tmp.path().to_path_buf())
        };
        let mut r = Reservoir::open(cfg, schema).unwrap();
        let mut it = r.iterator_at(0);
        let mut seen = 0u64;
        for i in 0..20u64 {
            r.append(&Event::new(i as i64, vec![Value::I64(i as i64)]))
                .unwrap();
            // drain whatever is visible
            while it.next(|_, _| ()).unwrap().is_some() {
                seen += 1;
            }
            assert_eq!(seen, i + 1, "iterator sees appended event immediately");
        }
    }

    #[test]
    fn unpin_releases_and_reloads() {
        let (_tmp, r) = setup(32, 8);
        let mut it = r.iterator_at(0);
        it.next(|_, _| ()).unwrap();
        it.unpin();
        assert_eq!(it.next(|s, _| s).unwrap(), Some(1), "reload after unpin");
    }
}
