//! Deterministic fault injection for the crash-retry test suite.
//!
//! A *failpoint* is a named site in production code where a test (or the
//! `RAILGUN_FAILPOINTS` environment variable) can arm a fault: an
//! injected I/O error, or a hard process abort. Sites call
//! [`trigger`] (fallible paths — the armed fault surfaces as an `Err`)
//! or [`hit`] (boolean paths — "should this site fire now?"); both are
//! keyed by a static site name.
//!
//! ## Cost contract
//!
//! The module honors the engine's hot-path cost contract: **with the
//! `failpoints` cargo feature off (the default), every entry point is an
//! `#[inline(always)]` empty function** — no lock, no allocation, no
//! branch survives into the optimized build. The registry, with its
//! mutex-guarded map, only exists under `--features failpoints`, which
//! is used exclusively by the fault-injection CI job and the
//! `crash_retry` test target.
//!
//! ## Arming
//!
//! ```text
//! failpoint::arm("mlog.sync", Action::Fail { at: 2 });   // 2nd hit errors
//! failpoint::arm("server.abort_after_ingest", Action::Abort { at: 5 });
//! ```
//!
//! `Action::Fail` is **one-shot**: once fired the site disarms itself,
//! so the retry that follows the injected fault runs clean — the exact
//! shape of a transient fault. `Action::Abort` kills the process
//! (`std::process::abort`), modelling a crash; it is normally armed via
//! the environment in a child process:
//!
//! ```text
//! RAILGUN_FAILPOINTS="server.abort_after_ingest=abort@5" railgun serve …
//! ```
//!
//! (comma-separated `site=fail@N` / `site=abort@N` entries; `@N` counts
//! hits and defaults to 1). [`init_from_env`] parses the variable — the
//! serve entrypoint calls it at startup when the feature is compiled in.
//!
//! Every fired fault increments a global counter surfaced as the
//! `failpoints.triggered` telemetry row (always rendered; pinned to 0 in
//! default builds).

/// What an armed failpoint does when its hit count reaches `at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Return an injected I/O error from the `at`-th hit, then disarm
    /// (one-shot: the retry after the fault runs clean).
    Fail {
        /// 1-based hit index that fires the fault.
        at: u64,
    },
    /// Abort the process on the `at`-th hit (crash model; stays armed,
    /// though the process does not survive to hit it twice).
    Abort {
        /// 1-based hit index that fires the fault.
        at: u64,
    },
}

#[cfg(feature = "failpoints")]
mod imp {
    use super::Action;
    use crate::error::{Error, Result};
    use once_cell::sync::Lazy;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    struct Armed {
        action: Action,
        hits: u64,
    }

    static REGISTRY: Lazy<Mutex<HashMap<String, Armed>>> =
        Lazy::new(|| Mutex::new(HashMap::new()));
    static TRIGGERED: AtomicU64 = AtomicU64::new(0);

    /// Arm `name` with `action` (replacing any previous arming and
    /// resetting its hit count).
    pub fn arm(name: &str, action: Action) {
        REGISTRY
            .lock()
            .unwrap()
            .insert(name.to_string(), Armed { action, hits: 0 });
    }

    /// Disarm one site.
    pub fn disarm(name: &str) {
        REGISTRY.lock().unwrap().remove(name);
    }

    /// Disarm every site (test isolation between scenarios).
    pub fn reset() {
        REGISTRY.lock().unwrap().clear();
    }

    /// Total faults fired since process start (the
    /// `failpoints.triggered` telemetry row).
    pub fn triggered_count() -> u64 {
        TRIGGERED.load(Ordering::Relaxed)
    }

    /// Parse `RAILGUN_FAILPOINTS` (`site=fail@N,site=abort@N`; `@N`
    /// defaults to 1) and arm each entry. Unparseable entries are
    /// skipped with a warning — a typo must not turn the fault harness
    /// into a crash of its own.
    pub fn init_from_env() {
        let Ok(spec) = std::env::var("RAILGUN_FAILPOINTS") else {
            return;
        };
        for entry in spec.split(',').filter(|s| !s.trim().is_empty()) {
            match parse_entry(entry.trim()) {
                Some((name, action)) => {
                    log::info!("failpoint armed from env: {name} -> {action:?}");
                    arm(name, action);
                }
                None => log::warn!("RAILGUN_FAILPOINTS: skipping bad entry '{entry}'"),
            }
        }
    }

    /// Arm every entry of a `site=fail@N,site=abort@N` spec (the CLI's
    /// `--fault` flag). Unlike the forgiving env path, a bad entry is an
    /// error: a CLI user wants a typo rejected, not skipped.
    pub fn arm_spec(spec: &str) -> Result<()> {
        for entry in spec.split(',').filter(|s| !s.trim().is_empty()) {
            match parse_entry(entry.trim()) {
                Some((name, action)) => {
                    log::info!("failpoint armed: {name} -> {action:?}");
                    arm(name, action);
                }
                None => {
                    return Err(Error::invalid(format!(
                        "bad failpoint entry '{entry}' (want site=fail@N or site=abort@N)"
                    )))
                }
            }
        }
        Ok(())
    }

    fn parse_entry(entry: &str) -> Option<(&str, Action)> {
        let (name, rhs) = entry.split_once('=')?;
        let (kind, at) = match rhs.split_once('@') {
            Some((kind, n)) => (kind, n.parse::<u64>().ok()?),
            None => (rhs, 1),
        };
        if at == 0 {
            return None;
        }
        match kind {
            "fail" => Some((name, Action::Fail { at })),
            "abort" => Some((name, Action::Abort { at })),
            _ => None,
        }
    }

    /// Record one hit of `name`; returns true when an armed `Fail`
    /// action fires (the caller then injects its fault). `Abort` actions
    /// never return.
    fn fire(name: &str) -> bool {
        let mut reg = REGISTRY.lock().unwrap();
        let Some(armed) = reg.get_mut(name) else {
            return false;
        };
        armed.hits += 1;
        match armed.action {
            Action::Fail { at } if armed.hits == at => {
                reg.remove(name); // one-shot
                TRIGGERED.fetch_add(1, Ordering::Relaxed);
                log::warn!("failpoint '{name}' fired (injected error)");
                true
            }
            Action::Abort { at } if armed.hits == at => {
                TRIGGERED.fetch_add(1, Ordering::Relaxed);
                log::warn!("failpoint '{name}' fired (process abort)");
                // stderr too: abort skips the logger's flush
                eprintln!("failpoint '{name}' fired: aborting process");
                std::process::abort();
            }
            _ => false,
        }
    }

    /// Fallible-site entry point: `Err` when an armed fault fires.
    pub fn trigger(name: &str) -> Result<()> {
        if fire(name) {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                format!("failpoint '{name}' injected error"),
            )));
        }
        Ok(())
    }

    /// Boolean-site entry point: true when an armed fault fires.
    pub fn hit(name: &str) -> bool {
        fire(name)
    }
}

#[cfg(not(feature = "failpoints"))]
mod imp {
    use super::Action;
    use crate::error::Result;

    /// No-op (default build: failpoints compiled out).
    #[inline(always)]
    pub fn arm(_name: &str, _action: Action) {}

    /// No-op (default build: failpoints compiled out).
    #[inline(always)]
    pub fn disarm(_name: &str) {}

    /// No-op (default build: failpoints compiled out).
    #[inline(always)]
    pub fn reset() {}

    /// Always 0 (default build: failpoints compiled out).
    #[inline(always)]
    pub fn triggered_count() -> u64 {
        0
    }

    /// No-op (default build: failpoints compiled out).
    #[inline(always)]
    pub fn init_from_env() {}

    /// Always an error (default build: failpoints compiled out) — the
    /// CLI's `--fault` flag must not silently arm nothing.
    pub fn arm_spec(_spec: &str) -> Result<()> {
        Err(crate::error::Error::invalid(
            "failpoints are compiled out of this binary; \
             rebuild with `--features failpoints` to use --fault",
        ))
    }

    /// Always `Ok` (default build: failpoints compiled out).
    #[inline(always)]
    pub fn trigger(_name: &str) -> Result<()> {
        Ok(())
    }

    /// Always false (default build: failpoints compiled out).
    #[inline(always)]
    pub fn hit(_name: &str) -> bool {
        false
    }
}

pub use imp::{arm, arm_spec, disarm, hit, init_from_env, reset, trigger, triggered_count};

#[cfg(all(test, not(feature = "failpoints")))]
mod feature_off_tests {
    use super::*;

    /// The default build must carry zero fault-injection behaviour:
    /// every site is an inert no-op, arming is a silent no-op, and the
    /// only surface that *reports* anything — `arm_spec`, used by
    /// `--fault` — refuses so operators aren't fooled into thinking a
    /// fault was injected.
    #[test]
    fn failpoint_feature_off_sites_are_inert() {
        arm("t.off", Action::Fail { at: 1 });
        assert!(trigger("t.off").is_ok());
        assert!(!hit("t.off"));
        assert_eq!(triggered_count(), 0);
        assert!(arm_spec("t.off=fail@1").is_err());
        init_from_env();
        reset();
    }
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    #[test]
    fn fail_action_is_one_shot_at_nth_hit() {
        reset();
        arm("t.site", Action::Fail { at: 3 });
        let before = triggered_count();
        assert!(trigger("t.site").is_ok());
        assert!(trigger("t.site").is_ok());
        let err = trigger("t.site").unwrap_err();
        assert!(err.to_string().contains("t.site"), "{err}");
        assert_eq!(triggered_count(), before + 1);
        // disarmed after firing: the retry runs clean
        assert!(trigger("t.site").is_ok());
    }

    #[test]
    fn unarmed_sites_never_fire() {
        reset();
        assert!(trigger("t.unarmed").is_ok());
        assert!(!hit("t.unarmed"));
    }

    #[test]
    fn hit_variant_fires_and_disarms() {
        reset();
        arm("t.bool", Action::Fail { at: 2 });
        assert!(!hit("t.bool"));
        assert!(hit("t.bool"));
        assert!(!hit("t.bool"));
    }

    #[test]
    fn arm_spec_arms_and_rejects_typos() {
        reset();
        arm_spec("t.spec=fail@2").unwrap();
        assert!(trigger("t.spec").is_ok());
        assert!(trigger("t.spec").is_err());
        assert!(arm_spec("t.spec=flail@2").is_err(), "bad action kind");
        assert!(arm_spec("t.spec").is_err(), "missing '='");
        assert!(arm_spec("t.spec=fail@0").is_err(), "zero hit index");
        reset();
    }

    #[test]
    fn env_spec_parses_fail_and_abort_with_counts() {
        reset();
        // parse_entry is private; exercise via arm + the documented
        // formats through a synthetic env var name is racy across test
        // threads, so drive the parser through init_from_env only when
        // the var is absent (no-op) and via direct arming otherwise.
        std::env::remove_var("RAILGUN_FAILPOINTS");
        init_from_env(); // absent: no-op, nothing armed
        assert!(trigger("t.env").is_ok());
    }
}
