//! Task-processor state snapshots for bounded-replay recovery.
//!
//! A [`Snapshot`] captures everything a task processor's recovery would
//! otherwise rebuild by replaying the mlog tail: the group interner
//! (canonical key bytes + display strings, in dense id order), the
//! state-store aggregate states (raw kvstore pairs), the plan's window
//! bookkeeping (per-bundle reservoir positions + the evaluation clock),
//! the count of mlog records the snapshot covers, and the per-producer
//! dedup high-water marks observed up to that point.
//!
//! [`CheckpointStore`] persists snapshots under
//! `<task dir>/checkpoints/` with the atomicity discipline the rest of
//! the engine uses: encode, write to a `.tmp` sibling, fsync, rename
//! into place, fsync the directory. Files are CRC'd and versioned; the
//! newest [`RETAIN`] snapshots are kept. A torn, corrupt, or
//! mid-write-crashed snapshot is detected at load time and recovery
//! falls back to the next-older snapshot or a full replay — never wrong
//! state.
//!
//! Failpoint sites (see [`crate::failpoint`]; compiled out by default):
//!
//! * `checkpoint.write_torn` — the snapshot file is truncated half-way
//!   but still renamed into place (a torn write on a non-atomic
//!   filesystem); the CRC catches it at recovery.
//! * `checkpoint.abort_mid_write` — fires between the temp write and
//!   the rename; armed as `abort@N` the process dies leaving only a
//!   `.tmp` (never consulted by recovery), armed as `fail@N` the write
//!   errors and the temp file is removed.
//! * `checkpoint.fsync` — an injected fsync error; the write fails
//!   cleanly and the engine continues without a new snapshot.

use crate::error::{Error, Result};
use crate::failpoint;
use crate::util::varint;
use std::io::Write;
use std::path::{Path, PathBuf};

/// `RGCK` little-endian: checkpoint file magic.
pub const MAGIC: u32 = 0x4b43_4752;
/// On-disk snapshot format version.
pub const VERSION: u32 = 1;
/// Snapshots kept per task (newest first; older ones are deleted).
pub const RETAIN: usize = 3;

const HEADER_LEN: usize = 4 + 4 + 4 + 8;

/// One task processor's recovery state at a known mlog position.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Snapshot {
    /// Entity topic the task consumes.
    pub topic: String,
    /// Partition within the topic.
    pub partition: u32,
    /// Mlog records processed (== reservoir events appended) when the
    /// snapshot was taken; recovery seeks the consumer here and replays
    /// only `[processed, log end)`.
    pub processed: u64,
    /// The plan's evaluation clock (`Plan::last_t_eval`) at snapshot
    /// time.
    pub last_t_eval: i64,
    /// Per-bundle reservoir positions: `(window offset_ms, iterator
    /// seq)` as returned by `Plan::positions`.
    pub positions: Vec<(i64, u64)>,
    /// Interner entries `(canonical key bytes, display string)` in
    /// dense `GroupId` order — restoring them in order reproduces the
    /// exact id assignment.
    pub interner: Vec<(Vec<u8>, String)>,
    /// Raw state-store pairs (composed key → encoded `AggState`), the
    /// same bytes an eviction spill writes.
    pub states: Vec<(Vec<u8>, Vec<u8>)>,
    /// Per-producer dedup high-water `(producer_id, max batch_seq)`
    /// observed in record seq tags up to `processed`. The broker
    /// rebuilds dedup state from the tags themselves; this documents
    /// the coverage the snapshot asserts.
    pub producers: Vec<(u32, u32)>,
}

impl Snapshot {
    /// Serialize: `[magic][version][crc][body_len][body]`, all header
    /// fields little-endian u32/u64, the body varint-encoded.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(256 + self.states.len() * 32);
        varint::write_str(&mut body, &self.topic);
        varint::write_u32(&mut body, self.partition);
        varint::write_u64(&mut body, self.processed);
        varint::write_i64(&mut body, self.last_t_eval);
        varint::write_u64(&mut body, self.positions.len() as u64);
        for &(offset_ms, seq) in &self.positions {
            varint::write_i64(&mut body, offset_ms);
            varint::write_u64(&mut body, seq);
        }
        varint::write_u64(&mut body, self.interner.len() as u64);
        for (key, display) in &self.interner {
            varint::write_bytes(&mut body, key);
            varint::write_str(&mut body, display);
        }
        varint::write_u64(&mut body, self.states.len() as u64);
        for (key, value) in &self.states {
            varint::write_bytes(&mut body, key);
            varint::write_bytes(&mut body, value);
        }
        varint::write_u64(&mut body, self.producers.len() as u64);
        for &(pid, max_seq) in &self.producers {
            varint::write_u32(&mut body, pid);
            varint::write_u32(&mut body, max_seq);
        }
        let mut out = Vec::with_capacity(HEADER_LEN + body.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&crc32fast::hash(&body).to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode and verify a snapshot file image. Any torn, truncated,
    /// bit-flipped or trailing-garbage buffer is rejected.
    pub fn decode(buf: &[u8]) -> Result<Snapshot> {
        if buf.len() < HEADER_LEN {
            return Err(Error::corrupt("snapshot: shorter than header"));
        }
        let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(Error::corrupt("snapshot: bad magic"));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::corrupt(format!(
                "snapshot: unsupported version {version}"
            )));
        }
        let crc = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        let body_len = u64::from_le_bytes(buf[12..20].try_into().unwrap()) as usize;
        let body = buf
            .get(HEADER_LEN..)
            .filter(|b| b.len() == body_len)
            .ok_or_else(|| Error::corrupt("snapshot: body length mismatch"))?;
        if crc32fast::hash(body) != crc {
            return Err(Error::corrupt("snapshot: crc mismatch"));
        }
        let mut pos = 0usize;
        let topic = varint::read_str(body, &mut pos)?.to_string();
        let partition = varint::read_u32(body, &mut pos)?;
        let processed = varint::read_u64(body, &mut pos)?;
        let last_t_eval = varint::read_i64(body, &mut pos)?;
        let n = varint::read_u64(body, &mut pos)? as usize;
        let mut positions = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let offset_ms = varint::read_i64(body, &mut pos)?;
            let seq = varint::read_u64(body, &mut pos)?;
            positions.push((offset_ms, seq));
        }
        let n = varint::read_u64(body, &mut pos)? as usize;
        let mut interner = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let key = varint::read_bytes(body, &mut pos)?.to_vec();
            let display = varint::read_str(body, &mut pos)?.to_string();
            interner.push((key, display));
        }
        let n = varint::read_u64(body, &mut pos)? as usize;
        let mut states = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let key = varint::read_bytes(body, &mut pos)?.to_vec();
            let value = varint::read_bytes(body, &mut pos)?.to_vec();
            states.push((key, value));
        }
        let n = varint::read_u64(body, &mut pos)? as usize;
        let mut producers = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let pid = varint::read_u32(body, &mut pos)?;
            let max_seq = varint::read_u32(body, &mut pos)?;
            producers.push((pid, max_seq));
        }
        if pos != body.len() {
            return Err(Error::corrupt("snapshot: trailing bytes in body"));
        }
        Ok(Snapshot {
            topic,
            partition,
            processed,
            last_t_eval,
            positions,
            interner,
            states,
            producers,
        })
    }
}

/// Directory of durable snapshots for one task processor.
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating) the snapshot directory and sweep crash debris:
    /// a `.tmp` left by a process that died mid-write is deleted — it
    /// was never renamed into place, so it is never recovery-relevant.
    pub fn open(dir: PathBuf) -> Result<CheckpointStore> {
        std::fs::create_dir_all(&dir)?;
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.extension().map(|e| e == "tmp").unwrap_or(false) {
                log::warn!("checkpoint: removing stray temp file {path:?}");
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(CheckpointStore { dir })
    }

    /// The snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(processed: u64) -> String {
        // zero-padded so lexical order == numeric order
        format!("snap-{processed:020}.rgc")
    }

    /// Atomically persist a snapshot (temp + fsync + rename + dir
    /// fsync), then prune to the newest [`RETAIN`] files. Returns the
    /// encoded byte count.
    pub fn write(&self, snap: &Snapshot) -> Result<u64> {
        let bytes = snap.encode();
        // torn-write model: the file is truncated but still renamed
        // into place, as a non-atomic filesystem could leave it
        let torn = failpoint::hit("checkpoint.write_torn");
        let write_len = if torn { bytes.len() / 2 } else { bytes.len() };
        let final_path = self.dir.join(Self::file_name(snap.processed));
        let tmp_path = self.dir.join(format!("{}.tmp", Self::file_name(snap.processed)));
        let result = (|| -> Result<()> {
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(&bytes[..write_len])?;
            // an Abort arming dies here, leaving only the .tmp behind
            failpoint::trigger("checkpoint.abort_mid_write")?;
            failpoint::trigger("checkpoint.fsync")?;
            f.sync_all()?;
            Ok(())
        })();
        if let Err(e) = result {
            let _ = std::fs::remove_file(&tmp_path);
            return Err(e);
        }
        std::fs::rename(&tmp_path, &final_path)?;
        std::fs::File::open(&self.dir)?.sync_all()?;
        self.prune()?;
        Ok(bytes.len() as u64)
    }

    /// Snapshot files, newest (highest `processed`) first.
    pub fn list(&self) -> Result<Vec<PathBuf>> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().map(|e| e == "rgc").unwrap_or(false))
            .collect();
        files.sort();
        files.reverse();
        Ok(files)
    }

    /// Load and verify one snapshot file.
    pub fn load(&self, path: &Path) -> Result<Snapshot> {
        Snapshot::decode(&std::fs::read(path)?)
    }

    fn prune(&self) -> Result<()> {
        for stale in self.list()?.into_iter().skip(RETAIN) {
            let _ = std::fs::remove_file(&stale);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Rng;
    use crate::util::tmp::TempDir;

    /// Deterministic snapshot from a seed, covering empty and populated
    /// sections, multi-byte UTF-8 displays and full-range clocks.
    fn snapshot_from_seed(seed: u64) -> Snapshot {
        let mut rng = Rng::new(seed);
        let n_pos = rng.index(4);
        let n_groups = rng.index(20);
        let n_states = rng.index(20);
        let n_prod = rng.index(5);
        Snapshot {
            topic: format!("payments.card{}", rng.index(3)),
            partition: rng.next_below(8) as u32,
            processed: rng.next_below(u64::MAX / 2),
            last_t_eval: rng.range_i64(i64::MIN / 2, i64::MAX / 2),
            positions: (0..n_pos)
                .map(|_| {
                    (
                        rng.range_i64(-1_000_000, 1_000_000),
                        rng.next_below(1 << 40),
                    )
                })
                .collect(),
            interner: (0..n_groups)
                .map(|i| {
                    let klen = rng.index(12);
                    let key: Vec<u8> = (0..klen).map(|_| rng.next_below(256) as u8).collect();
                    let display = if rng.chance(0.2) {
                        format!("cπrd{i}")
                    } else {
                        format!("card{i}")
                    };
                    (key, display)
                })
                .collect(),
            states: (0..n_states)
                .map(|_| {
                    let k: Vec<u8> = (0..rng.index(16)).map(|_| rng.next_below(256) as u8).collect();
                    let v: Vec<u8> = (0..rng.index(24)).map(|_| rng.next_below(256) as u8).collect();
                    (k, v)
                })
                .collect(),
            producers: (0..n_prod)
                .map(|_| (rng.next_below(1 << 20) as u32, rng.next_below(1 << 30) as u32))
                .collect(),
        }
    }

    #[test]
    fn snapshot_roundtrip_property() {
        check(
            "snapshot encode/decode roundtrip",
            300,
            |rng| rng.next_below(u64::MAX / 2),
            |&seed| {
                let snap = snapshot_from_seed(seed);
                let bytes = snap.encode();
                let back = Snapshot::decode(&bytes).map_err(|e| e.to_string())?;
                if back != snap {
                    return Err("decoded snapshot != original".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_truncation_is_rejected() {
        check(
            "snapshot truncation rejection",
            60,
            |rng| rng.next_below(u64::MAX / 2),
            |&seed| {
                let bytes = snapshot_from_seed(seed).encode();
                for cut in 0..bytes.len() {
                    if Snapshot::decode(&bytes[..cut]).is_ok() {
                        return Err(format!("cut {cut}/{} accepted", bytes.len()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_bit_flip_is_rejected() {
        // a flip in the body breaks the CRC; a flip in the header breaks
        // magic/version/crc/length — no single-byte corruption may load
        check(
            "snapshot bit-flip rejection",
            150,
            |rng| {
                (
                    rng.next_below(u64::MAX / 2),
                    rng.next_below(u64::MAX / 2),
                    (1 + rng.next_below(255)) as u8,
                )
            },
            |&(seed, pos_sel, xor)| {
                let mut bytes = snapshot_from_seed(seed).encode();
                let pos = (pos_sel % bytes.len() as u64) as usize;
                bytes[pos] ^= xor;
                match Snapshot::decode(&bytes) {
                    Err(_) => Ok(()),
                    Ok(_) => Err(format!("flip at {pos} accepted")),
                }
            },
        );
    }

    fn small(processed: u64) -> Snapshot {
        Snapshot {
            topic: "payments.card".into(),
            partition: 0,
            processed,
            last_t_eval: 42,
            positions: vec![(0, processed)],
            interner: vec![(b"k".to_vec(), "k".into())],
            states: vec![(b"sk".to_vec(), b"sv".to_vec())],
            producers: vec![(1, 7)],
        }
    }

    #[test]
    fn store_writes_atomically_and_retains_newest() {
        let tmp = TempDir::new("ckpt_store");
        let store = CheckpointStore::open(tmp.join("checkpoints")).unwrap();
        for processed in [10u64, 20, 30, 40, 50] {
            let bytes = store.write(&small(processed)).unwrap();
            assert!(bytes > HEADER_LEN as u64);
        }
        let files = store.list().unwrap();
        assert_eq!(files.len(), RETAIN, "older snapshots pruned");
        let newest = store.load(&files[0]).unwrap();
        assert_eq!(newest.processed, 50);
        let oldest_kept = store.load(&files[RETAIN - 1]).unwrap();
        assert_eq!(oldest_kept.processed, 30);
        // no temp debris after clean writes
        assert!(std::fs::read_dir(store.dir())
            .unwrap()
            .all(|e| e.unwrap().path().extension().unwrap() == "rgc"));
    }

    #[test]
    fn open_sweeps_stale_temp_files() {
        let tmp = TempDir::new("ckpt_tmp_sweep");
        let dir = tmp.join("checkpoints");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("snap-00000000000000000010.rgc.tmp"), b"junk").unwrap();
        let store = CheckpointStore::open(dir).unwrap();
        assert!(store.list().unwrap().is_empty());
        assert!(std::fs::read_dir(store.dir()).unwrap().next().is_none());
    }

    #[test]
    fn corrupt_file_fails_load_but_older_remains() {
        let tmp = TempDir::new("ckpt_corrupt");
        let store = CheckpointStore::open(tmp.join("checkpoints")).unwrap();
        store.write(&small(10)).unwrap();
        store.write(&small(20)).unwrap();
        let files = store.list().unwrap();
        // corrupt the newest in place
        let mut bytes = std::fs::read(&files[0]).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&files[0], &bytes).unwrap();
        assert!(store.load(&files[0]).is_err());
        assert_eq!(store.load(&files[1]).unwrap().processed, 10);
    }

    #[cfg(feature = "failpoints")]
    mod failpoint_sites {
        use super::*;
        use crate::failpoint::{self, Action};

        #[test]
        fn failpoint_torn_write_is_detected_at_load() {
            failpoint::reset();
            let tmp = TempDir::new("ckpt_torn");
            let store = CheckpointStore::open(tmp.join("checkpoints")).unwrap();
            store.write(&small(10)).unwrap();
            failpoint::arm("checkpoint.write_torn", Action::Fail { at: 1 });
            store.write(&small(20)).unwrap();
            failpoint::reset();
            let files = store.list().unwrap();
            assert_eq!(files.len(), 2, "the torn file was renamed into place");
            assert!(store.load(&files[0]).is_err(), "torn newest rejected");
            assert_eq!(store.load(&files[1]).unwrap().processed, 10);
        }

        #[test]
        fn failpoint_mid_write_failure_leaves_no_file() {
            failpoint::reset();
            let tmp = TempDir::new("ckpt_abort");
            let store = CheckpointStore::open(tmp.join("checkpoints")).unwrap();
            failpoint::arm("checkpoint.abort_mid_write", Action::Fail { at: 1 });
            assert!(store.write(&small(10)).is_err());
            failpoint::reset();
            assert!(store.list().unwrap().is_empty());
            assert!(
                std::fs::read_dir(store.dir()).unwrap().next().is_none(),
                "failed write cleans up its temp file"
            );
            // the site is one-shot: the next write goes through
            store.write(&small(20)).unwrap();
            assert_eq!(store.list().unwrap().len(), 1);
        }

        #[test]
        fn failpoint_fsync_failure_is_clean() {
            failpoint::reset();
            let tmp = TempDir::new("ckpt_fsync");
            let store = CheckpointStore::open(tmp.join("checkpoints")).unwrap();
            failpoint::arm("checkpoint.fsync", Action::Fail { at: 1 });
            assert!(store.write(&small(10)).is_err());
            failpoint::reset();
            assert!(store.list().unwrap().is_empty());
            store.write(&small(10)).unwrap();
            assert_eq!(store.load(&store.list().unwrap()[0]).unwrap().processed, 10);
        }
    }
}
